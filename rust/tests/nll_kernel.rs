//! ISSUE 5 acceptance: the blocked GEMM-based NLL/gradient engine on
//! the plane-major design layout must (a) reproduce the pre-refactor
//! row-at-a-time kernel (kept as `nll_grad_reference`) to ≤ 1e-9
//! relative tolerance on random designs — in fact the accumulation
//! orders are preserved, so most pins here are bitwise — and (b) stay
//! bit-identical across thread counts {1, 2, 8}, including when driven
//! end-to-end through the facade.
//!
//! Since PR 8 the kernels dispatch per [`KernelBackend`]: agreement
//! with the scalar reference is bitwise on the Scalar backend and
//! ≤ 1e-12 relative on Simd (which forks the FP summation order), so
//! the reference-comparison pins branch on the ambient backend. The
//! same-backend pins (thread counts, value-vs-grad, facade) are
//! backend-independent and stay bitwise unconditionally.

use mctm_coreset::basis::Design;
use mctm_coreset::linalg::simd::backend;
use mctm_coreset::mctm::{
    self, nll_grad_reference, nll_grad_with, nll_parts_with, ModelSpec, Params,
};
use mctm_coreset::prelude::*;
use mctm_coreset::util::parallel::Pool;

fn random_design(n: usize, j: usize, d: usize, seed: u64) -> Design {
    let mut rng = Rng::new(seed);
    let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
    Design::build(&data, d, 0.01)
}

fn random_params(spec: ModelSpec, seed: u64) -> Params {
    let mut rng = Rng::new(seed);
    let x: Vec<f64> = (0..spec.n_params()).map(|_| 0.5 * rng.normal()).collect();
    Params::new(spec, x)
}

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Random weights with a few exact zeros — the blocked kernel must
/// skip zero-weight rows exactly like the row-at-a-time path.
fn random_weights(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            if i % 17 == 3 {
                0.0
            } else {
                rng.uniform(0.25, 3.25)
            }
        })
        .collect()
}

#[test]
fn blocked_kernel_matches_reference_on_random_designs() {
    // shapes straddle the ROW_CHUNK boundary (2048) and the 4-row
    // blocking remainder, J from bivariate to covertype-scale
    let shapes: [(usize, usize, usize); 5] =
        [(37, 2, 4), (500, 3, 8), (2048, 5, 6), (2100, 5, 8), (4099, 10, 5)];
    for (case, &(n, j, d)) in shapes.iter().enumerate() {
        let seed = 100 + case as u64;
        let design = random_design(n, j, d, seed);
        let spec = ModelSpec::new(j, d);
        let p = random_params(spec, seed + 1);
        for weights in [Vec::new(), random_weights(n, seed + 2)] {
            let (v_ref, g_ref) = nll_grad_reference(&design, &weights, &p);
            let (v, g) = nll_grad_with(&design, &weights, &p, &Pool::new(1));
            assert!(
                rel_close(v, v_ref, 1e-9),
                "case {case}: value {v} vs reference {v_ref}"
            );
            for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert!(
                    rel_close(*a, *b, 1e-9),
                    "case {case}: grad[{k}] {a} vs reference {b}"
                );
            }
            if backend() == KernelBackend::Scalar {
                // the Scalar blocked kernel preserves every accumulation
                // order of the reference, so agreement is bitwise
                assert_eq!(v.to_bits(), v_ref.to_bits(), "case {case}: value bits");
                for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "case {case}: grad[{k}] bits");
                }
            } else {
                // Simd forks the summation order; the pin tightens to
                // the backend contract of ≤ 1e-12 relative
                assert!(rel_close(v, v_ref, 1e-12), "case {case}: {v} vs {v_ref}");
                for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                    assert!(rel_close(*a, *b, 1e-12), "case {case}: grad[{k}] {a} vs {b}");
                }
            }
        }
    }
}

#[test]
fn masked_nonfinite_rows_cannot_poison_the_gradient() {
    // a NaN observation masked out with weight 0 must contribute
    // nothing — the reference kernel skips the row entirely, and the
    // blocked kernel's panel accumulation must do the same (0·NaN would
    // otherwise poison ∂θ)
    let n = 300usize;
    let mut rng = Rng::new(61);
    let mut raw: Vec<f64> = (0..n * 2).map(|_| rng.normal()).collect();
    raw[2 * 57] = f64::NAN; // row 57, column 0
    raw[2 * 200 + 1] = f64::INFINITY; // row 200, column 1
    let design = Design::build(&Mat::from_vec(n, 2, raw), 5, 0.01);
    let spec = ModelSpec::new(2, 5);
    let p = random_params(spec, 62);
    let mut w = vec![1.0; n];
    w[57] = 0.0;
    w[200] = 0.0;
    let (v_ref, g_ref) = nll_grad_reference(&design, &w, &p);
    assert!(v_ref.is_finite());
    assert!(g_ref.iter().all(|g| g.is_finite()));
    for t in [1usize, 2] {
        let (v, g) = nll_grad_with(&design, &w, &p, &Pool::new(t));
        // the masking semantics hold on every backend: finite results,
        // agreement with the reference per the backend contract
        assert!(v.is_finite(), "value at {t} threads");
        assert!(g.iter().all(|gk| gk.is_finite()), "gradient at {t} threads");
        if backend() == KernelBackend::Scalar {
            assert_eq!(v.to_bits(), v_ref.to_bits(), "value at {t} threads");
            for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "grad[{k}] at {t} threads");
            }
        } else {
            assert!(rel_close(v, v_ref, 1e-12), "value at {t} threads: {v} vs {v_ref}");
            for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert!(rel_close(*a, *b, 1e-12), "grad[{k}] at {t}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn blocked_kernel_bit_identical_across_threads() {
    // > ROW_CHUNK rows so the shards really merge through the tree
    let (n, j, d) = (3 * 2048 + 19, 4, 6);
    let design = random_design(n, j, d, 7);
    let spec = ModelSpec::new(j, d);
    let p = random_params(spec, 8);
    let w = random_weights(n, 9);
    let (v1, g1) = nll_grad_with(&design, &w, &p, &Pool::new(1));
    let theta = p.theta();
    let lam = p.lambda_block().to_vec();
    let parts1 = nll_parts_with(&design, &w, &theta, &lam, &Pool::new(1));
    for t in [2usize, 8] {
        let (vt, gt) = nll_grad_with(&design, &w, &p, &Pool::new(t));
        assert_eq!(v1.to_bits(), vt.to_bits(), "value differs at {t} threads");
        for (k, (a, b)) in g1.iter().zip(&gt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "grad[{k}] differs at {t} threads");
        }
        let pt = nll_parts_with(&design, &w, &theta, &lam, &Pool::new(t));
        assert_eq!(parts1.f1.to_bits(), pt.f1.to_bits(), "f1 differs at {t}");
        assert_eq!(parts1.f2.to_bits(), pt.f2.to_bits(), "f2 differs at {t}");
        assert_eq!(parts1.f3.to_bits(), pt.f3.to_bits(), "f3 differs at {t}");
    }
}

#[test]
fn facade_fit_bit_identical_across_thread_counts() {
    // the PR-2/3 style pin, re-run against the blocked kernel: the
    // whole coreset + L-BFGS fit through the facade must not depend on
    // the session's thread count
    let mut rng = Rng::new(55);
    let data = Dgp::NormalMixture.generate(5_000, &mut rng);
    let run = |threads: usize| {
        SessionBuilder::new()
            .method("l2-hull")
            .budget(80)
            .basis_size(6)
            .seed(23)
            .threads(threads)
            .max_iters(80)
            .build()
            .unwrap()
            .fit(&data)
            .unwrap()
    };
    let m1 = run(1);
    for t in [2usize, 8] {
        let mt = run(t);
        assert_eq!(
            m1.diagnostics().coreset.indices,
            mt.diagnostics().coreset.indices,
            "coreset differs at {t} threads"
        );
        for (k, (a, b)) in m1.params().x.iter().zip(&mt.params().x).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "fit param {k} differs at {t} threads");
        }
        assert_eq!(
            m1.diagnostics().fit_nll.to_bits(),
            mt.diagnostics().fit_nll.to_bits(),
            "fit NLL differs at {t} threads"
        );
    }
}

#[test]
fn value_and_value_grad_agree() {
    // nll (no-gradient path) and the value returned next to the
    // gradient must be the same number, bit for bit
    let design = random_design(700, 3, 7, 31);
    let spec = ModelSpec::new(3, 7);
    let p = random_params(spec, 32);
    let w = random_weights(700, 33);
    let v_only = mctm::nll_with(&design, &w, &p, &Pool::new(2));
    let (v, _) = nll_grad_with(&design, &w, &p, &Pool::new(2));
    assert_eq!(v_only.to_bits(), v.to_bits());
}
