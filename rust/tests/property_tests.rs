//! Randomized property tests over the core invariants (via the in-tree
//! `util::proptest` harness — the `proptest` crate is unavailable
//! offline, see DESIGN.md §5).

use mctm_coreset::basis::{Bernstein, Design, Scaler};
use mctm_coreset::coreset::hull::{dist_to_hull, select_hull_points};
use mctm_coreset::coreset::leverage::leverage_scores_ridged_with;
use mctm_coreset::coreset::merge_reduce::{reduce, WeightedRows};
use mctm_coreset::coreset::Method;
use mctm_coreset::linalg::{Cholesky, Mat};
use mctm_coreset::mctm::{self, ModelSpec, Params};
use mctm_coreset::prelude::SessionBuilder;
use mctm_coreset::util::parallel::{Pool, ROW_CHUNK};
use mctm_coreset::util::proptest::{check, gen};
use mctm_coreset::util::rng::Rng;

fn bits_eq(a: &[f64], b: &[f64]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!("index {i}: {x:e} vs {y:e} differ bitwise"));
        }
    }
    Ok(())
}

#[test]
fn prop_bernstein_partition_of_unity() {
    check(
        "bernstein partition of unity",
        101,
        200,
        |rng| (gen::size(rng, 1, 12), rng.f64()),
        |&(m, x)| {
            let b = Bernstein::new(m);
            let s: f64 = b.eval(x).iter().sum();
            if (s - 1.0).abs() < 1e-10 {
                Ok(())
            } else {
                Err(format!("sum {s}"))
            }
        },
    );
}

#[test]
fn prop_theta_strictly_monotone() {
    check(
        "theta monotone under any beta",
        102,
        200,
        |rng| {
            let j = gen::size(rng, 1, 4);
            let d = gen::size(rng, 2, 9);
            let spec = ModelSpec::new(j, d);
            let x = gen::vec_in(rng, spec.n_params(), -4.0, 4.0);
            (spec, x)
        },
        |(spec, x)| {
            let p = Params::new(*spec, x.clone());
            let theta = p.theta();
            for jj in 0..spec.j {
                for k in 1..spec.d {
                    let (a, b) = (theta[jj * spec.d + k - 1], theta[jj * spec.d + k]);
                    if b <= a {
                        return Err(format!("theta[{jj},{k}] {b} <= {a}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nll_gradient_matches_fd() {
    check(
        "analytic gradient ≈ finite difference",
        103,
        15,
        |rng| {
            let j = gen::size(rng, 1, 3);
            let d = gen::size(rng, 3, 6);
            let n = gen::size(rng, 5, 30);
            let data = Mat::from_vec(n, j, gen::vec_normal(rng, n * j));
            let spec = ModelSpec::new(j, d);
            let x = gen::vec_in(rng, spec.n_params(), -1.0, 1.0);
            (spec, data, x)
        },
        |(spec, data, x)| {
            let design = Design::build(data, spec.d, 0.01);
            let p = Params::new(*spec, x.clone());
            let (_, g) = mctm::nll_grad(&design, &[], &p);
            let h = 1e-6;
            for k in 0..spec.n_params() {
                let mut xp = x.clone();
                xp[k] += h;
                let mut xm = x.clone();
                xm[k] -= h;
                let fp = mctm::nll(&design, &[], &Params::new(*spec, xp));
                let fm = mctm::nll(&design, &[], &Params::new(*spec, xm));
                let fd = (fp - fm) / (2.0 * h);
                if (g[k] - fd).abs() > 1e-3 * (1.0 + fd.abs()) {
                    return Err(format!("param {k}: {} vs {fd}", g[k]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coresets_valid_for_any_method_and_size() {
    check(
        "coreset validity",
        104,
        25,
        |rng| {
            let n = gen::size(rng, 30, 400);
            let k = gen::size(rng, 5, n);
            let data = Mat::from_vec(n, 2, gen::vec_normal(rng, n * 2));
            // registry-driven: new strategies are property-tested the
            // moment they are registered
            let all = Method::all();
            let m = all[rng.usize(all.len())];
            (data, k, m, rng.next_u64())
        },
        |(data, k, m, seed)| {
            // through the facade: builder → session → coreset report
            let cs = SessionBuilder::new()
                .method_tag(*m)
                .budget(*k)
                .basis_size(5)
                .seed(*seed)
                .build()
                .map_err(|e| e.to_string())?
                .coreset(data)
                .map_err(|e| e.to_string())?;
            if cs.size == 0 {
                return Err("empty coreset".into());
            }
            let indices = cs.indices.as_deref().ok_or("batch path must report indices")?;
            if indices.len() != cs.weights.len() {
                return Err("length mismatch".into());
            }
            if indices.iter().any(|&i| i >= data.rows) {
                return Err("index out of range".into());
            }
            if cs.weights.iter().any(|&w| !(w > 0.0) || !w.is_finite()) {
                return Err("invalid weight".into());
            }
            if cs.size > *k + 2 {
                return Err(format!("oversize {} > k={k}", cs.size));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hull_distance_semantics() {
    check(
        "hull distance: zero for members, nonneg always",
        105,
        40,
        |rng| {
            let n = gen::size(rng, 5, 60);
            let d = gen::size(rng, 2, 6);
            let pts = Mat::from_vec(n, d, gen::vec_normal(rng, n * d));
            let hsize = gen::size(rng, 1, n);
            (pts, hsize, rng.next_u64())
        },
        |(pts, hsize, seed)| {
            let mut rng = Rng::new(*seed);
            let hull = select_hull_points(pts, *hsize, &mut rng);
            if hull.is_empty() {
                return Err("empty hull".into());
            }
            for &h in &hull {
                let dist = dist_to_hull(pts, &hull, pts.row(h));
                if dist > 1e-9 {
                    return Err(format!("member {h} dist {dist}"));
                }
            }
            for r in 0..pts.rows {
                let dist = dist_to_hull(pts, &hull, pts.row(r));
                if !(dist >= 0.0) {
                    return Err(format!("negative dist {dist}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cholesky_solves_psd_systems() {
    check(
        "cholesky solve residual",
        106,
        50,
        |rng| {
            let d = gen::size(rng, 1, 12);
            let n = d + gen::size(rng, 1, 40);
            let x = Mat::from_vec(n, d, gen::vec_normal(rng, n * d));
            let b = gen::vec_normal(rng, d);
            (x, b)
        },
        |(x, b)| {
            let mut g = x.gram();
            for i in 0..g.rows {
                *g.at_mut(i, i) += 1e-9;
            }
            let ch = Cholesky::new(&g).map_err(|e| e.to_string())?;
            let sol = ch.solve(b);
            for i in 0..g.rows {
                let mut r = -b[i];
                for jj in 0..g.cols {
                    r += g.at(i, jj) * sol[jj];
                }
                if r.abs() > 1e-6 * (1.0 + b[i].abs()) {
                    return Err(format!("residual {r} at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_merge_reduce_size_and_weights() {
    check(
        "merge-reduce reduce() respects k and weight positivity",
        107,
        20,
        |rng| {
            let n = gen::size(rng, 20, 300);
            let k = gen::size(rng, 5, 100);
            let rows = Mat::from_vec(n, 2, gen::vec_normal(rng, n * 2));
            let w = gen::vec_in(rng, n, 0.5, 3.0);
            (rows, w, k, rng.next_u64())
        },
        |(rows, w, k, seed)| {
            let set = WeightedRows::new(rows.clone(), w.clone());
            let mut rng = Rng::new(*seed);
            let sink = mctm_coreset::util::degrade::DegradeSink::new();
            let red = reduce(&set, Method::L2Hull, *k, 5, 0.01, &mut rng, &sink)
                .map_err(|e| format!("reduce failed: {e}"))?;
            if red.len() > (*k).max(set.len().min(*k)) && red.len() > *k {
                return Err(format!("size {} > k {k}", red.len()));
            }
            if red.weights.iter().any(|&x| !(x > 0.0)) {
                return Err("non-positive weight".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_leverage_bit_identical_and_reproducible() {
    // the parallel leverage kernel must equal the serial reference
    // (Pool::new(1)) bit for bit at any thread count, and repeated runs
    // at the same thread count must reproduce exactly
    check(
        "leverage scores: parallel == serial, bitwise",
        109,
        4,
        |rng| {
            // span several ROW_CHUNK shards, with a ragged tail
            let n = ROW_CHUNK * gen::size(rng, 1, 3) + gen::size(rng, 0, 500);
            let d = gen::size(rng, 3, 10);
            Mat::from_vec(n, d, gen::vec_normal(rng, n * d))
        },
        |x| {
            let reference =
                leverage_scores_ridged_with(x, 0.0, &Pool::new(1)).map_err(|e| e.to_string())?;
            for t in [1usize, 2, 8] {
                let got = leverage_scores_ridged_with(x, 0.0, &Pool::new(t))
                    .map_err(|e| e.to_string())?;
                bits_eq(&got, &reference).map_err(|e| format!("threads={t}: {e}"))?;
                let again = leverage_scores_ridged_with(x, 0.0, &Pool::new(t))
                    .map_err(|e| e.to_string())?;
                bits_eq(&again, &got).map_err(|e| format!("rerun threads={t}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_nll_parts_and_grad_bit_identical() {
    // f1/f2/f3, the total NLL and the full gradient from the sharded
    // kernels must be bit-identical to the serial reference at any
    // thread count (weighted case included)
    check(
        "NLL parts + gradient: parallel == serial, bitwise",
        110,
        3,
        |rng| {
            let j = gen::size(rng, 2, 3);
            let d = gen::size(rng, 4, 6);
            let n = ROW_CHUNK * gen::size(rng, 1, 2) + gen::size(rng, 1, 300);
            let data = Mat::from_vec(n, j, gen::vec_normal(rng, n * j));
            let spec = ModelSpec::new(j, d);
            let x = gen::vec_in(rng, spec.n_params(), -1.0, 1.0);
            let w = gen::vec_in(rng, n, 0.1, 2.0);
            (spec, data, x, w)
        },
        |(spec, data, x, w)| {
            let design = Design::build(data, spec.d, 0.01);
            let p = Params::new(*spec, x.clone());
            let theta = p.theta();
            let lam = p.lambda_block().to_vec();
            let serial = Pool::new(1);
            let ref_parts = mctm::nll_parts_with(&design, w, &theta, &lam, &serial);
            let (ref_v, ref_g) = mctm::nll_grad_with(&design, w, &p, &serial);
            for t in [2usize, 8] {
                let pool = Pool::new(t);
                let parts = mctm::nll_parts_with(&design, w, &theta, &lam, &pool);
                bits_eq(
                    &[parts.f1, parts.f2, parts.f3],
                    &[ref_parts.f1, ref_parts.f2, ref_parts.f3],
                )
                .map_err(|e| format!("parts threads={t}: {e}"))?;
                let (v, g) = mctm::nll_grad_with(&design, w, &p, &pool);
                bits_eq(&[v], &[ref_v]).map_err(|e| format!("nll threads={t}: {e}"))?;
                bits_eq(&g, &ref_g).map_err(|e| format!("grad threads={t}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_basis_build_bit_identical() {
    // row-sharded Bernstein design construction writes disjoint chunks;
    // a/ad must match the serial build exactly at any thread count
    check(
        "basis build: parallel == serial, bitwise",
        111,
        4,
        |rng| {
            let n = ROW_CHUNK * gen::size(rng, 1, 2) + gen::size(rng, 0, 700);
            let j = gen::size(rng, 1, 3);
            let d = gen::size(rng, 2, 8);
            (Mat::from_vec(n, j, gen::vec_normal(rng, n * j)), d)
        },
        |(data, d)| {
            let scaler = Scaler::fit(data, 0.01);
            let reference =
                Design::build_with_scaler_on(data, *d, scaler.clone(), &Pool::new(1));
            for t in [2usize, 8] {
                let got = Design::build_with_scaler_on(data, *d, scaler.clone(), &Pool::new(t));
                bits_eq(&got.a, &reference.a).map_err(|e| format!("a threads={t}: {e}"))?;
                bits_eq(&got.ad, &reference.ad).map_err(|e| format!("ad threads={t}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scaled_data_in_unit_interval() {
    check(
        "scaler maps into [eps, 1-eps]",
        108,
        50,
        |rng| {
            let n = gen::size(rng, 2, 100);
            Mat::from_vec(n, 3, gen::vec_in(rng, n * 3, -1e3, 1e3))
        },
        |data| {
            let design = Design::build(data, 4, 0.01);
            let scaled = design.scaler.transform(data);
            for v in &scaled.data {
                if !(0.01 - 1e-12..=0.99 + 1e-12).contains(v) {
                    return Err(format!("scaled value {v}"));
                }
            }
            Ok(())
        },
    );
}
