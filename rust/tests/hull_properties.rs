//! Property tests for the parallel deterministic geometry layer
//! (ISSUE 2 tentpole): Frank–Wolfe hull distances, greedy hull
//! selection, and the John-ellipsoid rounding scans.
//!
//! The bit-identity tests are the acceptance pins: `select_hull_points`
//! and the ellipsoid rounding must produce identical output for any
//! thread count (here {1, 2, 8}), because the sampling probabilities
//! and hull augmentation feeding Algorithm 1 must not depend on the
//! machine's core count.

use mctm_coreset::coreset::ellipsoid::{ellipsoid_scores_with, john_ellipsoid_with};
use mctm_coreset::coreset::hull::{
    dist_to_hull, dist_to_hull_batch, select_hull_points, select_hull_points_with,
};
use mctm_coreset::linalg::Mat;
use mctm_coreset::util::parallel::Pool;
use mctm_coreset::util::proptest::{check, gen};
use mctm_coreset::util::rng::Rng;

fn normal_cloud(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect())
}

/// Convex combinations of hull members lie inside conv(hull), so their
/// hull distance must be ~zero. Frank–Wolfe is an O(1/M)-approximate
/// projection (M = 64 iterations), so the tolerance is loose, not 1e-12.
#[test]
fn prop_dist_near_zero_inside_hull() {
    check(
        "convex combinations of hull points have ~zero distance",
        201,
        30,
        |rng| {
            let n = gen::size(rng, 8, 80);
            let d = gen::size(rng, 2, 4);
            let pts = Mat::from_vec(n, d, gen::vec_normal(rng, n * d));
            (pts, rng.next_u64())
        },
        |(pts, seed)| {
            let mut rng = Rng::new(*seed);
            let hull = select_hull_points(pts, 8, &mut rng);
            for _ in 0..5 {
                let mut wsum = 0.0;
                let mut q = vec![0.0; pts.cols];
                for &h in &hull {
                    let w = rng.f64() + 1e-3;
                    wsum += w;
                    for (qk, xk) in q.iter_mut().zip(pts.row(h)) {
                        *qk += w * xk;
                    }
                }
                q.iter_mut().for_each(|x| *x /= wsum);
                let dist = dist_to_hull(pts, &hull, &q);
                if dist > 1e-2 {
                    return Err(format!("interior point at squared distance {dist}"));
                }
            }
            Ok(())
        },
    );
}

/// dist_to_hull is monotone non-increasing as hull points are added —
/// the invariant the lazy-greedy upper-bound cache in
/// `select_hull_points` relies on. Checked over nested prefixes of one
/// greedy selection, with slack for the finite Frank–Wolfe budget.
#[test]
fn prop_dist_monotone_as_hull_grows() {
    check(
        "dist_to_hull non-increasing in the hull",
        202,
        30,
        |rng| {
            let n = gen::size(rng, 10, 100);
            let d = gen::size(rng, 2, 5);
            (Mat::from_vec(n, d, gen::vec_normal(rng, n * d)), rng.next_u64())
        },
        |(pts, seed)| {
            let mut rng = Rng::new(*seed);
            let hull = select_hull_points(pts, 10, &mut rng);
            for probe in 0..pts.rows.min(20) {
                let q = pts.row(probe);
                let mut prev = f64::INFINITY;
                for m in 1..=hull.len() {
                    let cur = dist_to_hull(pts, &hull[..m], q);
                    if cur > prev * 1.05 + 1e-6 {
                        return Err(format!(
                            "probe {probe}, |S|={m}: {cur} > previous {prev}"
                        ));
                    }
                    prev = cur;
                }
            }
            Ok(())
        },
    );
}

/// The hull distance is a function of the point SET: permuting the rows
/// (and remapping the hull indices) must not change it.
#[test]
fn prop_dist_invariant_under_row_permutation() {
    check(
        "hull distance invariant under row permutation",
        203,
        40,
        |rng| {
            let n = gen::size(rng, 6, 60);
            let d = gen::size(rng, 2, 5);
            (Mat::from_vec(n, d, gen::vec_normal(rng, n * d)), rng.next_u64())
        },
        |(pts, seed)| {
            let mut rng = Rng::new(*seed);
            let hull = select_hull_points(pts, 6, &mut rng);
            let mut perm: Vec<usize> = (0..pts.rows).collect();
            rng.shuffle(&mut perm);
            let ppts = pts.select_rows(&perm);
            // position of original row r in the permuted matrix
            let mut pos = vec![0usize; pts.rows];
            for (new_i, &old_i) in perm.iter().enumerate() {
                pos[old_i] = new_i;
            }
            let phull: Vec<usize> = hull.iter().map(|&h| pos[h]).collect();
            for probe in 0..pts.rows.min(12) {
                let a = dist_to_hull(pts, &hull, pts.row(probe));
                let b = dist_to_hull(&ppts, &phull, ppts.row(pos[probe]));
                if (a - b).abs() > 1e-9 * (1.0 + a.abs()) {
                    return Err(format!("probe {probe}: {a} vs permuted {b}"));
                }
            }
            Ok(())
        },
    );
}

/// ACCEPTANCE PIN: hull selection is bit-identical for threads {1,2,8},
/// on both the all-candidates path (n ≤ 4096) and the
/// support-prefiltered path (n > 4096) with multiple greedy rounds.
#[test]
fn select_hull_points_bit_identical_across_threads() {
    for (n, d, k, seed) in [(500usize, 3usize, 12usize, 11u64), (6_000, 4, 16, 13)] {
        let pts = normal_cloud(n, d, seed);
        let reference =
            select_hull_points_with(&pts, k, &mut Rng::new(seed ^ 0xA5), &Pool::new(1));
        assert!(!reference.is_empty(), "n={n}: empty selection");
        for t in [2usize, 8] {
            let got =
                select_hull_points_with(&pts, k, &mut Rng::new(seed ^ 0xA5), &Pool::new(t));
            assert_eq!(got, reference, "selection differs at threads={t}, n={n}");
        }
    }
}

/// The batched API must agree with per-query calls bit for bit at any
/// thread count (the scratch reuse may not change a single rounding).
#[test]
fn dist_to_hull_batch_matches_single_bitwise() {
    let n = 3_000;
    let pts = normal_cloud(n, 4, 17);
    let mut rng = Rng::new(19);
    let hull = select_hull_points(&pts, 10, &mut rng);
    let idx: Vec<usize> = (0..n).step_by(3).collect();
    let queries = pts.select_rows(&idx);
    let reference: Vec<f64> = (0..queries.rows)
        .map(|r| dist_to_hull(&pts, &hull, queries.row(r)))
        .collect();
    for t in [1usize, 2, 8] {
        let got = dist_to_hull_batch(&pts, &hull, &queries, &Pool::new(t));
        assert_eq!(got.len(), reference.len());
        for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={t}, query {i}: {a} vs {b}");
        }
    }
}

/// ACCEPTANCE PIN: the John-ellipsoid rounding loop (weighted moment
/// rebuild + violator scan) and the final scoring pass are bit-identical
/// for threads {1,2,8}. n spans several ROW_CHUNK shards with a ragged
/// tail.
#[test]
fn ellipsoid_rounding_bit_identical_across_threads() {
    let x = normal_cloud(2_500, 3, 23);
    let je_ref = john_ellipsoid_with(&x, 0.05, 120, &Pool::new(1));
    let s_ref = ellipsoid_scores_with(&x, 0.05, &Pool::new(1));
    for t in [2usize, 8] {
        let je = john_ellipsoid_with(&x, 0.05, 120, &Pool::new(t));
        assert_eq!(je.iters, je_ref.iters, "iteration count differs at threads={t}");
        for (i, (a, b)) in je.u.iter().zip(&je_ref.u).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={t}, u[{i}]");
        }
        for (i, (a, b)) in je.m.data.iter().zip(&je_ref.m.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={t}, moment entry {i}");
        }
        let s = ellipsoid_scores_with(&x, 0.05, &Pool::new(t));
        for (i, (a, b)) in s.iter().zip(&s_ref).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "threads={t}, score {i}");
        }
    }
}

/// Batch queries against a hull spanning > ROW_CHUNK rows: the chunk
/// grid must cover every query exactly once (ragged-tail regression).
#[test]
fn batch_covers_ragged_tail() {
    let pts = normal_cloud(2_049, 2, 29);
    let mut rng = Rng::new(31);
    let hull = select_hull_points(&pts, 6, &mut rng);
    let out = dist_to_hull_batch(&pts, &hull, &pts, &Pool::new(4));
    assert_eq!(out.len(), 2_049);
    assert!(out.iter().all(|d| d.is_finite() && *d >= 0.0));
    // selected hull members project onto themselves
    for &h in &hull {
        assert!(out[h] < 1e-9, "hull member {h} at distance {}", out[h]);
    }
}
