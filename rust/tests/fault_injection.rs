//! Deterministic fault-injection suite (ISSUE 6): drives the public
//! facade (`SessionBuilder` → `Session::coreset`/`fit`) against
//! [`FaultySource`]-wrapped shard streams with seeded [`FaultPlan`]s.
//!
//! The headline invariants:
//!   * a run whose transient faults are recovered by the bounded retry
//!     loop is **bit-identical** to the fault-free run — at every
//!     consumer fan-out × thread count combination;
//!   * unrecoverable faults surface as typed `ApiError::Stream` with
//!     shard provenance within bounded time — no panic, no hang — at
//!     queue capacities down to 1 (maximum backpressure);
//!   * every numerical / ingestion fallback is visible in
//!     `CoresetReport::degradations` rather than a log line.

use mctm_coreset::coreset::leverage::leverage_scores_ridged_sink;
use mctm_coreset::prelude::*;
use mctm_coreset::util::parallel::Pool;
use std::time::Duration;

const TOTAL: usize = 6_000;
const SHARD: usize = 1_000;

/// A fresh fault-free generator stream; the same `seed` always yields
/// the same shard sequence, so a `FaultySource` wrapping it sees the
/// identical underlying data as a clean run.
fn clean_source(seed: u64) -> GenShards<impl FnMut(usize) -> Mat> {
    let mut rng = Rng::new(seed);
    GenShards::new(
        move |n| Dgp::BivariateNormal.generate(n, &mut rng),
        2,
        TOTAL,
        SHARD,
    )
}

/// Erase the source type so the facade takes the streaming path for
/// both clean and fault-wrapped sources through one code path.
fn boxed(src: impl ShardSource + Send + 'static) -> Box<dyn ShardSource + Send> {
    Box::new(src)
}

fn session(consumers: usize, threads: usize, queue_cap: usize, policy: InvalidPolicy) -> Session {
    SessionBuilder::new()
        .method("l2-hull")
        .budget(60)
        .basis_size(5)
        .seed(11)
        .consumers(consumers)
        .threads(threads)
        .queue_cap(queue_cap)
        .on_invalid(policy)
        .build()
        .unwrap()
}

/// Run `f` on a helper thread and fail the test if it does not finish
/// within `secs` — the "no hang" half of the orderly-shutdown contract.
/// (The Rust test harness has no per-test timeout of its own, so a
/// deadlocked pipeline would otherwise wedge CI forever.)
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("pipeline did not shut down within the timeout")
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------- (a)
// transient faults + retries are invisible in the result

#[test]
fn transient_faults_recover_bit_identically_across_fanout() {
    let clean = session(1, 1, 4, InvalidPolicy::Error)
        .coreset(boxed(clean_source(7)))
        .unwrap();
    assert!(clean.degradations.is_clean(), "{:?}", clean.degradations);
    assert_eq!(clean.n_seen, TOTAL);

    for consumers in [1, 4] {
        for threads in [1, 2, 8] {
            let faulty = FaultySource::new(
                clean_source(7),
                FaultPlan::new(13).with_transients(2, SHARD_RETRY_LIMIT),
            );
            let report = with_timeout(120, move || {
                session(consumers, threads, 4, InvalidPolicy::Error)
                    .coreset(boxed(faulty))
                    .unwrap()
            });
            assert_eq!(
                bits(&report.rows.data),
                bits(&clean.rows.data),
                "rows differ at consumers={consumers} threads={threads}"
            );
            assert_eq!(
                bits(&report.weights),
                bits(&clean.weights),
                "weights differ at consumers={consumers} threads={threads}"
            );
            assert_eq!(report.n_seen, TOTAL);
            // ... but the retries themselves are on the record
            assert!(report.degradations.shard_retries > 0);
        }
    }
}

#[test]
fn spurious_empty_shards_leave_no_trace_in_the_result() {
    let clean = session(2, 2, 4, InvalidPolicy::Error)
        .coreset(boxed(clean_source(19)))
        .unwrap();
    let faulty = FaultySource::new(clean_source(19), FaultPlan::new(5).with_empty_shards(2));
    let report = session(2, 2, 4, InvalidPolicy::Error)
        .coreset(boxed(faulty))
        .unwrap();
    assert_eq!(bits(&report.rows.data), bits(&clean.rows.data));
    assert_eq!(bits(&report.weights), bits(&clean.weights));
    assert_eq!(report.n_seen, TOTAL);
    assert!(report.degradations.empty_shards_skipped > 0);
}

// ---------------------------------------------------------------- (b)
// fatal faults: typed errors with provenance, orderly shutdown

#[test]
fn fatal_fault_surfaces_typed_stream_error_without_hanging() {
    // queue_cap 1 is maximum backpressure (producer blocks on a full
    // 1-slot channel while the abort propagates); 4 is the default
    for queue_cap in [1, 4] {
        for consumers in [1, 4] {
            let faulty =
                FaultySource::new(clean_source(7), FaultPlan::new(3).with_fatal_at(2));
            let err = with_timeout(120, move || {
                session(consumers, 1, queue_cap, InvalidPolicy::Error)
                    .coreset(boxed(faulty))
                    .unwrap_err()
            });
            match &err {
                ApiError::Stream { shard_seq, .. } => assert_eq!(
                    *shard_seq,
                    Some(2),
                    "queue_cap={queue_cap} consumers={consumers}"
                ),
                other => panic!("expected ApiError::Stream, got {other}"),
            }
            assert!(err.to_string().contains("fatal"), "{err}");
        }
    }
}

#[test]
fn exhausted_transient_retries_escalate_to_typed_error() {
    // one more consecutive failure than the retry budget ⇒ the bounded
    // loop gives up on the very first shard and reports it
    let faulty = FaultySource::new(
        clean_source(7),
        FaultPlan::new(17).with_transients(1, SHARD_RETRY_LIMIT + 1),
    );
    let err = with_timeout(120, move || {
        session(2, 1, 4, InvalidPolicy::Error)
            .coreset(boxed(faulty))
            .unwrap_err()
    });
    match &err {
        ApiError::Stream { shard_seq, .. } => assert_eq!(*shard_seq, Some(0)),
        other => panic!("expected ApiError::Stream, got {other}"),
    }
    assert!(err.to_string().contains("retries exhausted"), "{err}");
}

#[test]
fn truncated_stream_ends_cleanly_with_partial_data() {
    // truncation is an early end-of-stream, not a fault: the pipeline
    // finishes with whatever arrived
    let faulty = FaultySource::new(clean_source(7), FaultPlan::new(2).with_truncation_at(3));
    let report = session(2, 2, 4, InvalidPolicy::Error)
        .coreset(boxed(faulty))
        .unwrap();
    assert_eq!(report.n_seen, 3 * SHARD);
    assert!(report.size > 0);
}

// ---------------------------------------------------------------- (c)
// ingestion policies + numerical degradation visibility

#[test]
fn nan_poison_with_error_policy_names_the_cell() {
    let faulty = FaultySource::new(clean_source(7), FaultPlan::new(29).with_nan_cells(2));
    let err = with_timeout(120, move || {
        session(2, 1, 4, InvalidPolicy::Error)
            .coreset(boxed(faulty))
            .unwrap_err()
    });
    match &err {
        ApiError::Stream { shard_seq, .. } => assert!(shard_seq.is_some()),
        other => panic!("expected ApiError::Stream, got {other}"),
    }
    let msg = err.to_string();
    assert!(msg.contains("non-finite"), "{msg}");
    assert!(msg.contains("row") && msg.contains("column"), "{msg}");
}

#[test]
fn mask_and_drop_policies_degrade_gracefully_on_the_record() {
    let faulty = FaultySource::new(clean_source(7), FaultPlan::new(29).with_nan_cells(2));
    let masked = session(2, 2, 4, InvalidPolicy::MaskRow)
        .coreset(boxed(faulty))
        .unwrap();
    assert_eq!(masked.n_seen, TOTAL, "masking keeps every row");
    assert!(masked.degradations.invalid_cells > 0);
    assert!(masked.degradations.rows_masked > 0);

    let faulty = FaultySource::new(clean_source(7), FaultPlan::new(29).with_nan_cells(2));
    let dropped = session(2, 2, 4, InvalidPolicy::DropRow)
        .coreset(boxed(faulty))
        .unwrap();
    assert!(dropped.degradations.rows_dropped > 0);
    assert_eq!(
        dropped.n_seen,
        TOTAL - dropped.degradations.rows_dropped,
        "n_seen counts only the rows that survived scrubbing"
    );
}

#[test]
fn batch_sources_respect_the_invalid_policy_too() {
    let mut rng = Rng::new(31);
    let mut data = Dgp::BivariateNormal.generate(500, &mut rng);
    data.data[2 * 7 + 1] = f64::NAN;
    data.data[2 * 100] = f64::INFINITY;

    let err = session(1, 1, 4, InvalidPolicy::Error)
        .coreset(&data)
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("non-finite") && msg.contains("row 7"), "{msg}");

    let report = session(1, 1, 4, InvalidPolicy::MaskRow)
        .coreset(&data)
        .unwrap();
    assert_eq!(report.degradations.invalid_cells, 2);
    assert_eq!(report.degradations.rows_masked, 2);
    assert_eq!(report.n_seen, 500);
}

#[test]
fn ridge_ladder_recovery_is_recorded_not_fatal() {
    // rows split between e₁ and e₂ give Gram = diag(5, 5); γ = −6 makes
    // it diag(−1, −1) — indefinite, so the plain factorization fails and
    // only the escalating ridge ladder can recover it
    let mut v = Vec::with_capacity(20);
    for i in 0..10 {
        if i % 2 == 0 {
            v.extend_from_slice(&[1.0, 0.0]);
        } else {
            v.extend_from_slice(&[0.0, 1.0]);
        }
    }
    let x = Mat::from_vec(10, 2, v);
    let sink = DegradeSink::new();
    let scores = leverage_scores_ridged_sink(&x, -6.0, &Pool::new(2), &sink).unwrap();
    assert!(scores.iter().all(|s| s.is_finite() && *s >= 0.0));
    let d = sink.snapshot();
    assert!(d.gram_ridge_recoveries >= 1, "{d:?}");
    assert!(d.gram_ridge_max_rung >= 1, "{d:?}");
    assert!(!d.is_clean());
}

// ---------------------------------------------------------------- (d)
// fault machinery composes with the on-disk store (PR 9): the reader
// is just another ShardSource, so FaultySource wraps it for free

#[test]
fn store_backed_transients_recover_bit_identically() {
    let clean = session(1, 1, 4, InvalidPolicy::Error)
        .coreset(boxed(clean_source(7)))
        .unwrap();
    assert!(clean.degradations.is_clean(), "{:?}", clean.degradations);

    // drain the same generator shard-by-shard into a store, so the
    // store's chunk sequence is exactly the GenShards shard sequence
    let dir = std::env::temp_dir().join(format!("mctm_faultstore_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rows.store");
    {
        let mut src = clean_source(7);
        let mut w = StoreWriter::create(&path, 2, SHARD).unwrap();
        while let Some(shard) = src.next_shard().unwrap() {
            w.push_mat(&shard).unwrap();
        }
        assert_eq!(w.finish().unwrap(), TOTAL as u64);
    }

    // transient read faults injected on top of the disk reader recover
    // to the exact bits of the clean generator run — which also proves
    // store round-trip ≡ generator, end to end through the pipeline
    let faulty = FaultySource::new(
        StoreReader::open(&path).unwrap(),
        FaultPlan::new(13).with_transients(2, SHARD_RETRY_LIMIT),
    );
    let report = with_timeout(120, move || {
        session(2, 2, 4, InvalidPolicy::Error)
            .coreset(boxed(faulty))
            .unwrap()
    });
    assert_eq!(bits(&report.rows.data), bits(&clean.rows.data));
    assert_eq!(bits(&report.weights), bits(&clean.weights));
    assert_eq!(report.n_seen, TOTAL);
    assert!(report.degradations.shard_retries > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fit_diagnostics_carry_stream_degradations() {
    let mut rng = Rng::new(9);
    let gen = GenShards::new(
        move |n| Dgp::BivariateNormal.generate(n, &mut rng),
        2,
        3_000,
        500,
    );
    let faulty = FaultySource::new(gen, FaultPlan::new(21).with_nan_cells(3));
    let model = SessionBuilder::new()
        .budget(60)
        .basis_size(5)
        .seed(11)
        .consumers(2)
        .on_invalid(InvalidPolicy::MaskRow)
        .fit_options(FitOptions { max_iters: 60, ..Default::default() })
        .build()
        .unwrap()
        .fit(boxed(faulty))
        .unwrap();
    let d = &model.diagnostics().coreset.degradations;
    assert!(d.invalid_cells > 0, "{d:?}");
    assert!(d.rows_masked > 0, "{d:?}");
    assert!(model.diagnostics().fit_nll.is_finite());
}
