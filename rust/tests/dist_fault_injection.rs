//! Transport fault injection for the distributed coordinator (ISSUE
//! 10 acceptance): every injected failure mode — frame corruption,
//! connection drop, worker stall, a killed worker process, every
//! worker dead, an unresolvable job — either **recovers to the exact
//! fault-free bytes** or **surfaces a typed error**. Never a hang
//! (every run sits under a watchdog timeout), never a partial result,
//! never a panic.

use mctm_coreset::prelude::*;
use std::io::BufRead;
use std::time::Duration;

const TOTAL: usize = 6_000;
const SHARD: usize = 500;
const DATASET: &str = "bivariate-normal";

fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("distributed run did not finish within the timeout")
}

fn spawn_workers(n: usize) -> Vec<WorkerHandle> {
    (0..n)
        .map(|_| Worker::bind("127.0.0.1:0").unwrap().spawn().unwrap())
        .collect()
}

fn addrs(handles: &[WorkerHandle]) -> Vec<String> {
    handles.iter().map(|h| h.addr().to_string()).collect()
}

fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

fn config(workers: Vec<String>) -> DistConfig {
    let mut cfg = DistConfig::new(workers, DATASET, TOTAL, SHARD, Method::L2Hull, 40, 5, 0.01);
    cfg.seed = 23;
    cfg
}

/// The full bit pattern of a coreset: row data, weights, provenance.
fn coreset_bits(c: &mctm_coreset::coreset::merge_reduce::WeightedRows) -> Vec<u64> {
    let mut out: Vec<u64> = c.rows.data.iter().map(|v| v.to_bits()).collect();
    out.extend(c.weights.iter().map(|v| v.to_bits()));
    out.push(c.n_hull as u64);
    out
}

// --------------------------------------------------------------------
// injected transport faults recover to the exact fault-free bytes

#[test]
fn injected_faults_recover_to_the_exact_fault_free_bytes() {
    // fault-free reference, computed once over the same worker pool
    let (want, want_stats, want_record) = with_timeout(120, || {
        let handles = spawn_workers(2);
        let sink = DegradeSink::new();
        let out = run_distributed(&config(addrs(&handles)), &sink).unwrap();
        (coreset_bits(&out.0), out.1, sink.snapshot())
    });
    assert!(want_record.is_clean(), "fault-free run recorded degradations: {want_record}");

    // ordinal 1 is the first frame after the Hello reply — mid-range,
    // a Leaf (or a heartbeat Ping) already in flight
    let plans: [(&str, TransportFaultPlan); 3] = [
        ("corrupt", TransportFaultPlan::new(0xDEAD_BEEF_0BAD_CAFE).with_corrupt_at(1)),
        ("drop", TransportFaultPlan::new(7).with_drop_at(1)),
        ("stall", TransportFaultPlan::new(7).with_stall_at(1)),
    ];
    for (name, plan) in plans {
        let (got, got_stats, record) = with_timeout(120, move || {
            let handles = spawn_workers(2);
            let mut cfg = config(addrs(&handles));
            cfg.fault = Some(plan);
            let sink = DegradeSink::new();
            let out = run_distributed(&cfg, &sink).unwrap();
            (coreset_bits(&out.0), out.1, sink.snapshot())
        });
        assert_eq!(got, want, "{name}: recovered coreset differs from fault-free bytes");
        assert_eq!(got_stats.n_seen, want_stats.n_seen, "{name}");
        assert_eq!(got_stats.n_shards, want_stats.n_shards, "{name}");
        assert_eq!(got_stats.n_reduces, want_stats.n_reduces, "{name}");
        // the recovery is on the record: the range that hit the fault
        // was retried (and possibly reassigned), and data-level
        // counters stayed exactly-once across the re-execution
        assert!(
            record.worker_retries >= 1 || record.range_reassignments >= 1,
            "{name}: no recovery recorded despite an injected fault: {record}"
        );
        assert_eq!(record.empty_shards_skipped, want_record.empty_shards_skipped, "{name}");
        assert_eq!(record.shard_retries, want_record.shard_retries, "{name}");
    }
}

// --------------------------------------------------------------------
// a worker process killed mid-sketch: its range re-executes elsewhere,
// and the result is byte-identical to the in-process run — whatever
// instant the kill lands at

#[test]
fn killed_worker_process_recovers_bit_identically() {
    let session = |consumers: usize| {
        SessionBuilder::new()
            .method("l2-hull")
            .budget(40)
            .basis_size(5)
            .seed(23)
            .consumers(consumers)
            .threads(1)
            .build()
            .unwrap()
    };
    let baseline = session(2).coreset(NamedSource::stream(DATASET, TOTAL, SHARD)).unwrap();
    let want = Artifact::Sketch(baseline.to_artifact()).to_bytes();

    let mut children: Vec<std::process::Child> = (0..2)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_mctm-coreset"))
                .args(["work", "--listen", "127.0.0.1:0"])
                .stdout(std::process::Stdio::piped())
                .stderr(std::process::Stdio::null())
                .spawn()
                .expect("spawning worker process")
        })
        .collect();
    let workers: Vec<String> = children
        .iter_mut()
        .map(|child| {
            let stdout = child.stdout.take().expect("worker stdout is piped");
            let mut line = String::new();
            std::io::BufReader::new(stdout)
                .read_line(&mut line)
                .expect("reading worker announce line");
            line.trim()
                .strip_prefix("worker listening on ")
                .unwrap_or_else(|| panic!("unexpected announce line: {line:?}"))
                .to_string()
        })
        .collect();

    let runner = {
        let workers = workers.clone();
        std::thread::spawn(move || session(2).dist_coreset(&workers, DATASET, TOTAL, SHARD))
    };
    // let the run get going, then kill one worker process outright
    // (SIGKILL: no goodbye frame, sockets torn down by the kernel)
    std::thread::sleep(Duration::from_millis(150));
    children[0].kill().expect("killing worker 0");
    let _ = children[0].wait();

    let report = with_timeout(120, move || runner.join().expect("coordinator thread panicked"))
        .expect("run did not recover from the killed worker");
    assert_eq!(
        Artifact::Sketch(report.to_artifact()).to_bytes(),
        want,
        "recovered sketch differs from the in-process bytes"
    );

    for child in &mut children {
        let _ = child.kill();
        let _ = child.wait();
    }
}

// --------------------------------------------------------------------
// every worker dead: a typed error naming the failure, within the
// timeout — and the failed run leaves the sink untouched (the PR-6
// success-only accounting rule, extended to the transport level)

#[test]
fn all_workers_dead_is_a_typed_error_and_records_nothing() {
    let (err, record) = with_timeout(60, || {
        let sink = DegradeSink::new();
        let err = run_distributed(&config(vec![dead_addr()]), &sink).unwrap_err();
        (err, sink.snapshot())
    });
    match &err {
        ApiError::Stream { shard_seq, consumer, .. } => {
            assert_eq!(*shard_seq, Some(0));
            assert_eq!(*consumer, Some(0));
        }
        other => panic!("expected ApiError::Stream, got {other:?}"),
    }
    let msg = format!("{err:#}");
    assert!(
        msg.contains("exhausted its transport retry budget"),
        "error should name the exhausted budget: {msg}"
    );
    // exhausted attempts are failures, not recoveries: nothing counted
    assert!(record.is_clean(), "failed run leaked degradation counts: {record}");
}

// --------------------------------------------------------------------
// a job the worker cannot run (unknown dataset) comes back as a typed
// fatal error with worker provenance — not a retry loop, not a hang

#[test]
fn unknown_dataset_is_a_typed_fatal_error_with_provenance() {
    let err = with_timeout(60, || {
        let handles = spawn_workers(1);
        let sink = DegradeSink::new();
        let mut cfg = config(addrs(&handles));
        cfg.dataset = "no-such-dataset".into();
        let err = run_distributed(&cfg, &sink).unwrap_err();
        assert!(sink.snapshot().is_clean());
        drop(handles);
        err
    });
    match &err {
        ApiError::Stream { consumer, .. } => assert_eq!(*consumer, Some(0)),
        other => panic!("expected ApiError::Stream, got {other:?}"),
    }
    let msg = format!("{err:#}");
    assert!(
        msg.contains("no-such-dataset"),
        "error should name the dataset that failed to resolve: {msg}"
    );
}

// --------------------------------------------------------------------
// config validation stays typed at the distributed entrypoint

#[test]
fn empty_worker_list_and_zero_knobs_are_typed_config_errors() {
    let sink = DegradeSink::new();
    assert!(matches!(
        run_distributed(&config(vec![]), &sink).unwrap_err(),
        ApiError::Config { .. }
    ));
    let mut zero_shard = config(vec![dead_addr()]);
    zero_shard.shard = 0;
    assert!(matches!(
        run_distributed(&zero_shard, &sink).unwrap_err(),
        ApiError::Config { .. }
    ));
    let mut zero_retry = config(vec![dead_addr()]);
    zero_retry.retry_limit = 0;
    assert!(matches!(
        run_distributed(&zero_retry, &sink).unwrap_err(),
        ApiError::Config { .. }
    ));
    assert!(sink.snapshot().is_clean());
}
