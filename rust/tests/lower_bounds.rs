//! The paper's lower bounds (Lemmas 2.5 / 2.6) — constructive
//! demonstrations that coresets below the stated sizes cannot exist,
//! because dropping any observation of the adversarial instances makes
//! the squared part vanish for a parametrization where the full loss is
//! positive (no multiplicative guarantee possible).

use mctm_coreset::basis::Design;
use mctm_coreset::mctm::nll_parts;

/// Build a Design directly from prescribed basis tensors (bypassing the
/// Bernstein transform — the lower bounds are statements about the
/// abstract data matrix {a_ij}). `a`/`ad` must be in the plane-major
/// layout: element (i, j, k) at `j·n·d + i·d + k`.
fn design_from_rows(a: Vec<f64>, ad: Vec<f64>, n: usize, j: usize, d: usize) -> Design {
    use mctm_coreset::basis::Scaler;
    use mctm_coreset::linalg::Mat;
    let scaler = Scaler::fit(&Mat::zeros(2.max(n), j.max(1)), 0.01);
    Design { n, j, d, a, ad, scaler }
}

/// Lemma 2.6 instance: n = d observations with a_ij = e_i. Any subset
/// that misses an observation i₀ has zero f₁ along ϑ_j = e_{i₀},
/// while the full data has f₁ > 0 ⇒ size Ω(d) is necessary (per
/// component j ⇒ Ω(dJ) overall).
#[test]
fn lemma_2_6_any_proper_subset_fails() {
    let (n, j, d) = (6usize, 2usize, 6usize);
    // a_ij = e_i for all j (plane-major: margin jj's plane starts at jj·n·d)
    let mut a = vec![0.0; n * j * d];
    for i in 0..n {
        for jj in 0..j {
            a[(jj * n + i) * d + i] = 1.0;
        }
    }
    let ad = vec![1.0; n * j * d]; // irrelevant for f1
    let design = design_from_rows(a, ad, n, j, d);

    for dropped in 0..n {
        // adversarial parametrization: ϑ_j = e_dropped, λ = 0
        let mut theta = vec![0.0; j * d];
        for jj in 0..j {
            theta[jj * d + dropped] = 1.0;
        }
        let lam = vec![0.0; j * (j - 1) / 2];

        let full = nll_parts(&design, &[], &theta, &lam);
        assert!(full.f1 > 0.0, "full f1 must be positive");

        // the coreset: everything except `dropped`, ANY weights
        let keep: Vec<usize> = (0..n).filter(|&i| i != dropped).collect();
        let sub = design.select(&keep);
        for wscale in [1.0, 10.0, 1e6] {
            let w = vec![wscale; keep.len()];
            let part = nll_parts(&sub, &w, &theta, &lam);
            // exact equality is intentional: every per-row term is the
            // literal 0.0 (w·0.5·0²) and IEEE sums of exact zeros stay
            // exact through the tree reduction — keep the lemma pinned
            assert_eq!(
                part.f1, 0.0,
                "subset missing row {dropped} cannot represent f1"
            );
        }
    }
}

/// Lemma 2.5 instance (block staircase): rows a_{tj} = e_k for j ≥ j₀,
/// 0 otherwise. The parametrization λ_{j₂j₁} = 1, λ_{j₂,j₁−1} = −1
/// isolates the contribution of a single (block, row) pair, so every
/// one of the Θ(dJ²) pairs must be represented.
#[test]
fn lemma_2_5_block_isolation() {
    let (j, d) = (3usize, 2usize);
    // blocks indexed by (j0, k): J·d blocks of J rows each
    let n = j * d; // one observation per block
    let mut a = vec![0.0; n * j * d];
    for (blk, _) in (0..n).enumerate() {
        let j0 = blk % j;
        let k = blk / j;
        for jj in 0..j {
            if jj >= j0 {
                a[(jj * n + blk) * d + k] = 1.0;
            }
        }
    }
    let ad = vec![1.0; n * j * d];
    let design = design_from_rows(a, ad, n, j, d);

    // isolate block (j0=1, k=0) row j2=2: λ_{2,1} = 1, λ_{2,0} = −1,
    // ϑ_k = e_0 for all components
    let mut theta = vec![0.0; j * d];
    for jj in 0..j {
        theta[jj * d] = 1.0;
    }
    let spec = mctm_coreset::mctm::ModelSpec::new(j, d);
    let mut lam = vec![0.0; spec.n_lambda()];
    lam[spec.lambda_index(2, 1)] = 1.0;
    lam[spec.lambda_index(2, 0)] = -1.0;

    let full = nll_parts(&design, &[], &theta, &lam);
    assert!(full.f1 > 0.0);

    // find which observations carry the isolated contribution
    let mut carriers = Vec::new();
    for i in 0..n {
        let sub = design.select(&[i]);
        let part = nll_parts(&sub, &[], &theta, &lam);
        if part.f1 > 0.0 {
            carriers.push(i);
        }
    }
    // the staircase isolates a small carrier set; dropping all carriers
    // zeroes f1 while the full instance is positive
    assert!(!carriers.is_empty() && carriers.len() < n);
    let keep: Vec<usize> = (0..n).filter(|i| !carriers.contains(i)).collect();
    let sub = design.select(&keep);
    let part = nll_parts(&sub, &[], &theta, &lam);
    // exact equality for the same reason as in Lemma 2.6 above
    assert_eq!(part.f1, 0.0, "dropping the carriers must zero f1");
}

/// Positive counterpart: our ℓ₂ sampler puts non-zero probability on
/// every row of the Lemma-2.6 instance (leverage = 1 for each), so at
/// k = n it returns the exact dataset and preserves f₁ exactly.
#[test]
fn leverage_sampler_covers_adversarial_instance() {
    use mctm_coreset::coreset::leverage::mctm_leverage_scores;
    let (n, j, d) = (5usize, 2usize, 5usize);
    let mut a = vec![0.0; n * j * d];
    for i in 0..n {
        for jj in 0..j {
            a[(jj * n + i) * d + i] = 1.0;
        }
    }
    let ad = vec![1.0; n * j * d];
    let design = design_from_rows(a, ad, n, j, d);
    let u = mctm_leverage_scores(&design).unwrap();
    for (i, ui) in u.iter().enumerate() {
        assert!(
            (ui - 1.0).abs() < 1e-6,
            "row {i}: identity design has full leverage, got {ui}"
        );
    }
}
