//! ISSUE 5 acceptance: zero per-iteration heap allocation in the
//! optimizer loop. A counting global allocator measures allocations
//! around `minimize` runs that differ ONLY in iteration count — if the
//! drivers allocate anything per iteration, the longer run counts more.
//! A steady-state check on `NativeNll::value_grad_into` additionally
//! pins that the native objective's per-call cost is constant (the
//! reusable `Params` + `NllScratch` never re-grow); the only remaining
//! allocations are the per-chunk worker buffers below the pool,
//! amortized over `ROW_CHUNK` rows each.
//!
//! Everything runs inside ONE `#[test]` so no concurrent test can
//! perturb the global counter.

use mctm_coreset::basis::Design;
use mctm_coreset::fit::{minimize, FitOptions, NativeNll, Objective, OptimizerKind};
use mctm_coreset::prelude::*;
use mctm_coreset::util::parallel;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<F: FnOnce()>(f: F) -> usize {
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    ALLOCS.load(Ordering::SeqCst) - before
}

/// Chained Rosenbrock — smooth, slow to optimize (hundreds of
/// iterations at dim 32), and allocation-free to evaluate, so any
/// allocation measured below belongs to the driver loop.
struct RosenbrockChain(usize);

impl Objective for RosenbrockChain {
    fn dim(&self) -> usize {
        self.0
    }

    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let n = self.0;
        let mut v = 0.0;
        grad.fill(0.0);
        for i in 0..n - 1 {
            let t = x[i + 1] - x[i] * x[i];
            let u = 1.0 - x[i];
            v += 100.0 * t * t + u * u;
            grad[i] += -400.0 * x[i] * t - 2.0 * u;
            grad[i + 1] += 200.0 * t;
        }
        v
    }
}

fn start(n: usize) -> Vec<f64> {
    (0..n).map(|i| if i % 2 == 0 { -1.2 } else { 1.0 }).collect()
}

#[test]
fn optimizer_loops_are_allocation_free_per_iteration() {
    let dim = 32usize;
    let lbfgs = |max_iters: usize| FitOptions {
        optimizer: OptimizerKind::Lbfgs,
        max_iters,
        tol: 0.0, // never converge by tolerance — run exactly max_iters
        learning_rate: 0.05,
        history: 5,
    };
    let adam = |max_iters: usize| FitOptions {
        optimizer: OptimizerKind::Adam,
        max_iters,
        tol: 0.0,
        learning_rate: 0.02,
        history: 5,
    };

    // warm up lazy initialisation (thread-count resolution etc.)
    parallel::set_threads(1);
    let obj = RosenbrockChain(dim);
    let _ = minimize(&obj, start(dim), &lbfgs(3));
    let _ = minimize(&obj, start(dim), &adam(3));

    // L-BFGS: 4× the iterations must cost exactly the same allocations
    let mut iters_seen = (0usize, 0usize);
    let a_short = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &lbfgs(10));
        iters_seen.0 = iters;
    });
    let a_long = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &lbfgs(40));
        iters_seen.1 = iters;
    });
    assert_eq!(iters_seen, (10, 40), "runs must use exactly max_iters");
    assert_eq!(
        a_short, a_long,
        "L-BFGS allocates per iteration: {a_short} allocs over 10 iters vs {a_long} over 40"
    );

    // Adam: same invariance
    let b_short = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &adam(50));
        assert_eq!(iters, 50);
    });
    let b_long = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &adam(200));
        assert_eq!(iters, 200);
    });
    assert_eq!(
        b_short, b_long,
        "Adam allocates per iteration: {b_short} allocs over 50 iters vs {b_long} over 200"
    );

    // NativeNll steady state: per-call allocation count is constant —
    // the reusable Params/NllScratch never re-allocate, and what
    // remains is the fixed per-chunk worker cost inside the pool
    let mut rng = Rng::new(3);
    let data = Dgp::BivariateNormal.generate(2_100, &mut rng);
    let design = Design::build(&data, 6, 0.01);
    let spec = ModelSpec::new(2, 6);
    let native = NativeNll::new(spec, &design, Vec::new());
    let x = Params::init(spec).x;
    let mut grad = vec![0.0; native.dim()];
    native.value_grad_into(&x, &mut grad); // warm the scratch
    let five = allocs_during(|| {
        for _ in 0..5 {
            native.value_grad_into(&x, &mut grad);
        }
    });
    let ten = allocs_during(|| {
        for _ in 0..10 {
            native.value_grad_into(&x, &mut grad);
        }
    });
    assert_eq!(
        ten,
        2 * five,
        "NativeNll per-call allocation cost is not constant ({five} per 5 calls, {ten} per 10)"
    );
}
