//! ISSUE 5 acceptance: zero per-iteration heap allocation in the
//! optimizer loop. A counting global allocator measures allocations
//! around `minimize` runs that differ ONLY in iteration count — if the
//! drivers allocate anything per iteration, the longer run counts more.
//! A steady-state check on `NativeNll::value_grad_into` additionally
//! pins that the native objective's per-call cost is constant (the
//! reusable `Params` + `NllScratch` never re-grow); the only remaining
//! allocations are the per-chunk worker buffers below the pool,
//! amortized over `ROW_CHUNK` rows each.
//!
//! PR 8 extends the same discipline to the conditional objective
//! (`CondNll` reuses its `CondScratch` across calls), to the bootstrap
//! replicate loop (hoisted resample buffer + `Design::select_into` make
//! the allocation cost exactly linear in the replicate count), and to
//! `select_into` itself (zero allocations once the sub-design is at
//! capacity).
//!
//! Everything runs inside ONE `#[test]` so no concurrent test can
//! perturb the global counter.

use mctm_coreset::basis::Design;
use mctm_coreset::fit::{minimize, FitOptions, NativeNll, Objective, OptimizerKind};
use mctm_coreset::mctm::bootstrap_ci;
use mctm_coreset::mctm::conditional::{CondDesign, CondNll, CondSpec};
use mctm_coreset::prelude::*;
use mctm_coreset::util::parallel;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<F: FnOnce()>(f: F) -> usize {
    let before = ALLOCS.load(Ordering::SeqCst);
    f();
    ALLOCS.load(Ordering::SeqCst) - before
}

/// Chained Rosenbrock — smooth, slow to optimize (hundreds of
/// iterations at dim 32), and allocation-free to evaluate, so any
/// allocation measured below belongs to the driver loop.
struct RosenbrockChain(usize);

impl Objective for RosenbrockChain {
    fn dim(&self) -> usize {
        self.0
    }

    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let n = self.0;
        let mut v = 0.0;
        grad.fill(0.0);
        for i in 0..n - 1 {
            let t = x[i + 1] - x[i] * x[i];
            let u = 1.0 - x[i];
            v += 100.0 * t * t + u * u;
            grad[i] += -400.0 * x[i] * t - 2.0 * u;
            grad[i + 1] += 200.0 * t;
        }
        v
    }
}

fn start(n: usize) -> Vec<f64> {
    (0..n).map(|i| if i % 2 == 0 { -1.2 } else { 1.0 }).collect()
}

#[test]
fn optimizer_loops_are_allocation_free_per_iteration() {
    let dim = 32usize;
    let lbfgs = |max_iters: usize| FitOptions {
        optimizer: OptimizerKind::Lbfgs,
        max_iters,
        tol: 0.0, // never converge by tolerance — run exactly max_iters
        learning_rate: 0.05,
        history: 5,
    };
    let adam = |max_iters: usize| FitOptions {
        optimizer: OptimizerKind::Adam,
        max_iters,
        tol: 0.0,
        learning_rate: 0.02,
        history: 5,
    };

    // warm up lazy initialisation (thread-count resolution etc.)
    parallel::set_threads(1);
    let obj = RosenbrockChain(dim);
    let _ = minimize(&obj, start(dim), &lbfgs(3));
    let _ = minimize(&obj, start(dim), &adam(3));

    // L-BFGS: 4× the iterations must cost exactly the same allocations
    let mut iters_seen = (0usize, 0usize);
    let a_short = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &lbfgs(10));
        iters_seen.0 = iters;
    });
    let a_long = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &lbfgs(40));
        iters_seen.1 = iters;
    });
    assert_eq!(iters_seen, (10, 40), "runs must use exactly max_iters");
    assert_eq!(
        a_short, a_long,
        "L-BFGS allocates per iteration: {a_short} allocs over 10 iters vs {a_long} over 40"
    );

    // Adam: same invariance
    let b_short = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &adam(50));
        assert_eq!(iters, 50);
    });
    let b_long = allocs_during(|| {
        let (_, _, iters, _) = minimize(&obj, start(dim), &adam(200));
        assert_eq!(iters, 200);
    });
    assert_eq!(
        b_short, b_long,
        "Adam allocates per iteration: {b_short} allocs over 50 iters vs {b_long} over 200"
    );

    // NativeNll steady state: per-call allocation count is constant —
    // the reusable Params/NllScratch never re-allocate, and what
    // remains is the fixed per-chunk worker cost inside the pool
    let mut rng = Rng::new(3);
    let data = Dgp::BivariateNormal.generate(2_100, &mut rng);
    let design = Design::build(&data, 6, 0.01);
    let spec = ModelSpec::new(2, 6);
    let native = NativeNll::new(spec, &design, Vec::new());
    let x = Params::init(spec).x;
    let mut grad = vec![0.0; native.dim()];
    native.value_grad_into(&x, &mut grad); // warm the scratch
    let five = allocs_during(|| {
        for _ in 0..5 {
            native.value_grad_into(&x, &mut grad);
        }
    });
    let ten = allocs_during(|| {
        for _ in 0..10 {
            native.value_grad_into(&x, &mut grad);
        }
    });
    assert_eq!(
        ten,
        2 * five,
        "NativeNll per-call allocation cost is not constant ({five} per 5 calls, {ten} per 10)"
    );

    // CondNll steady state (PR 8): the panel-kernel conditional
    // objective reuses its CondScratch across calls, so its per-call
    // allocation cost is constant too — what remains is the fixed
    // per-chunk partial below the pool
    let q = 2usize;
    let nc = 2_100usize; // > ROW_CHUNK: two chunks per evaluation
    let y = Mat::from_vec(nc, 2, (0..nc * 2).map(|_| rng.normal()).collect());
    let xmat = Mat::from_vec(nc, q, (0..nc * q).map(|_| rng.normal()).collect());
    let cd = CondDesign::build(&y, &xmat, 5, 0.01);
    let cspec = CondSpec::new(2, 5, q);
    let cond = CondNll::new(cspec, &cd, Vec::new());
    let cx = vec![0.1; cond.dim()];
    let mut cgrad = vec![0.0; cond.dim()];
    cond.value_grad_into(&cx, &mut cgrad); // warm the scratch
    let five_c = allocs_during(|| {
        for _ in 0..5 {
            cond.value_grad_into(&cx, &mut cgrad);
        }
    });
    let ten_c = allocs_during(|| {
        for _ in 0..10 {
            cond.value_grad_into(&cx, &mut cgrad);
        }
    });
    assert_eq!(
        ten_c,
        2 * five_c,
        "CondNll per-call allocation cost is not constant ({five_c} per 5 calls, {ten_c} per 10)"
    );

    // Bootstrap replicate loop (PR 8): the resample index buffer, the
    // sub-design, the uniform replicate weights and the cold start are
    // hoisted out of the loop, so extra replicates cost an exactly
    // linear number of allocations. Adam has no line search, so each
    // replicate's two refits allocate a fixed, deterministic amount;
    // replicate counts stay well above the stable-sort small-slice
    // threshold so the percentile step costs the same per call.
    let bdata = Dgp::BivariateNormal.generate(400, &mut rng);
    let bdesign = Design::build(&bdata, 4, 0.01);
    let bspec = ModelSpec::new(2, 4);
    let bpoint = Params::init(bspec);
    let bopts = FitOptions {
        optimizer: OptimizerKind::Adam,
        max_iters: 8,
        tol: 0.0,
        learning_rate: 0.02,
        history: 5,
    };
    let run_boot = |reps: usize| {
        allocs_during(|| {
            let mut brng = Rng::new(11);
            std::hint::black_box(bootstrap_ci(
                &bdesign, &[], &bpoint, reps, 0.9, &bopts, &mut brng,
            ));
        })
    };
    let _ = run_boot(64); // warm lazy state
    let a64 = run_boot(64);
    let a96 = run_boot(96);
    let a128 = run_boot(128);
    assert_eq!(
        a128 - a96,
        a96 - a64,
        "bootstrap allocates superlinearly in replicates: {a64} @64, {a96} @96, {a128} @128"
    );

    // Design::select_into at capacity: re-gathering a same-size index
    // set into a warmed sub-design must not touch the allocator at all
    let idx: Vec<usize> = (0..200).map(|i| (7 * i) % bdesign.n).collect();
    let mut sub = bdesign.select(&idx); // warmed to capacity
    let gathers = allocs_during(|| {
        for _ in 0..4 {
            bdesign.select_into(&idx, &mut sub);
        }
    });
    assert_eq!(gathers, 0, "select_into allocated at capacity: {gathers} allocs");
}
