//! Distributed Merge & Reduce determinism suite (ISSUE 10): an
//! N-worker `dist_coreset`/`dist_fit` run must be **bit-identical** to
//! the single-process streaming run of the same session — pinned on
//! the saved `Artifact` bytes, so recovery correctness is testable as
//! plain byte equality — and must stay bit-identical when a worker in
//! the list is dead and its range has to be reassigned.

use mctm_coreset::prelude::*;
use std::time::Duration;

const TOTAL: usize = 6_000;
const SHARD: usize = 500;
const DATASET: &str = "bivariate-normal";

fn session(consumers: usize, threads: usize) -> Session {
    SessionBuilder::new()
        .method("l2-hull")
        .budget(40)
        .basis_size(5)
        .seed(23)
        .consumers(consumers)
        .threads(threads)
        .max_iters(60)
        .build()
        .unwrap()
}

/// Fail the test if `f` does not finish within `secs` — the "no hang"
/// half of the failure-semantics contract.
fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("distributed run did not finish within the timeout")
}

fn spawn_workers(n: usize) -> Vec<WorkerHandle> {
    (0..n)
        .map(|_| Worker::bind("127.0.0.1:0").unwrap().spawn().unwrap())
        .collect()
}

fn addrs(handles: &[WorkerHandle]) -> Vec<String> {
    handles.iter().map(|h| h.addr().to_string()).collect()
}

/// An address that is guaranteed dead: bind a listener to learn a free
/// port, then drop it — connections are refused from then on.
fn dead_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

fn sketch_bytes(report: &CoresetReport) -> Vec<u8> {
    Artifact::Sketch(report.to_artifact()).to_bytes()
}

fn model_bytes(model: &FittedModel) -> Vec<u8> {
    Artifact::Model(model.to_artifact()).to_bytes()
}

// ---------------------------------------------------------------- (a)
// N workers ≡ one process, byte for byte

#[test]
fn dist_coreset_matches_in_process_at_every_worker_count() {
    let baseline = session(2, 2).coreset(NamedSource::stream(DATASET, TOTAL, SHARD)).unwrap();
    assert_eq!(baseline.n_seen, TOTAL);
    let want = sketch_bytes(&baseline);

    for n_workers in [1usize, 2, 4] {
        let got = with_timeout(120, move || {
            let handles = spawn_workers(n_workers);
            let report = session(n_workers, 1)
                .dist_coreset(&addrs(&handles), DATASET, TOTAL, SHARD)
                .unwrap();
            // spinning down the workers here keeps the handles' Drop
            // out of the timing path of the next iteration
            drop(handles);
            report
        });
        assert_eq!(
            sketch_bytes(&got),
            want,
            "distributed sketch bytes differ from in-process at {n_workers} workers"
        );
        assert!(
            got.degradations.is_clean(),
            "clean run recorded degradations at {n_workers} workers: {}",
            got.degradations
        );
        // stream accounting survives the hop: same rows, same fixed
        // fold tree
        let stats = got.stream.expect("distributed report carries stream stats");
        assert_eq!(stats.n_seen, TOTAL);
        assert_eq!(stats.n_shards, baseline.stream.as_ref().unwrap().n_shards);
        assert_eq!(stats.n_reduces, baseline.stream.as_ref().unwrap().n_reduces);
    }
}

// ---------------------------------------------------------------- (b)
// dead worker in the list: range reassigns, bytes unchanged —
// at workers {1, 2, 4} × threads {1, 8}

#[test]
fn dead_worker_reassignment_is_invisible_in_the_artifact_bytes() {
    for n_workers in [1usize, 2, 4] {
        for threads in [1usize, 8] {
            let clean = session(n_workers, threads)
                .fit(NamedSource::stream(DATASET, TOTAL, SHARD))
                .unwrap();
            let (got_model, got_report) = with_timeout(180, move || {
                let handles = spawn_workers(n_workers);
                // the dead address is first, so at least one range is
                // tried on it, exhausts its transport budget, and gets
                // reassigned to a live worker
                let mut workers = vec![dead_addr()];
                workers.extend(addrs(&handles));
                let model = session(n_workers, threads)
                    .dist_fit(&workers, DATASET, TOTAL, SHARD)
                    .unwrap();
                drop(handles);
                let report = model.diagnostics().coreset.clone();
                (model, report)
            });
            assert_eq!(
                model_bytes(&got_model),
                model_bytes(&clean),
                "model bytes differ under reassignment at workers={n_workers} threads={threads}"
            );
            // ϑ, bitwise
            let got_x: Vec<u64> = got_model.params().x.iter().map(|v| v.to_bits()).collect();
            let want_x: Vec<u64> = clean.params().x.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_x, want_x);
            assert_eq!(
                sketch_bytes(&got_report),
                sketch_bytes(&clean.diagnostics().coreset),
                "sketch bytes differ under reassignment at workers={n_workers} threads={threads}"
            );
            // ... and the recovery is on the record, not silent
            assert!(
                got_report.degradations.range_reassignments >= 1,
                "expected a recorded reassignment at workers={n_workers} threads={threads}: {}",
                got_report.degradations
            );
        }
    }
}

// ---------------------------------------------------------------- (c)
// saved artifacts round-trip: dist-fit's file equals stream's file

#[test]
fn saved_dist_artifacts_equal_saved_stream_artifacts() {
    let dir = std::env::temp_dir().join(format!("mctm_dist_artifacts_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let clean = session(2, 2).fit(NamedSource::stream(DATASET, TOTAL, SHARD)).unwrap();
    let a = dir.join("stream.sketch.mctm");
    clean.diagnostics().coreset.save(&a).unwrap();

    let b = dir.join("dist.sketch.mctm");
    let dist = with_timeout(120, move || {
        let handles = spawn_workers(2);
        let model = session(2, 2).dist_fit(&addrs(&handles), DATASET, TOTAL, SHARD).unwrap();
        drop(handles);
        model
    });
    dist.diagnostics().coreset.save(&b).unwrap();

    assert_eq!(
        std::fs::read(&a).unwrap(),
        std::fs::read(&b).unwrap(),
        "persisted sketch artifacts differ between stream and dist-fit"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------- (d)
// sanity: the shard_retry_limit knob reaches the transport budget path
// (a 1-retry budget still recovers a refused-then-reassigned range)

#[test]
fn minimal_retry_budget_still_recovers_via_reassignment() {
    let clean = session(2, 1).coreset(NamedSource::stream(DATASET, TOTAL, SHARD)).unwrap();
    let got = with_timeout(120, move || {
        let handles = spawn_workers(2);
        let mut workers = vec![dead_addr()];
        workers.extend(addrs(&handles));
        let report = SessionBuilder::new()
            .method("l2-hull")
            .budget(40)
            .basis_size(5)
            .seed(23)
            .consumers(2)
            .threads(1)
            .max_iters(60)
            .shard_retry_limit(1)
            .build()
            .unwrap()
            .dist_coreset(&workers, DATASET, TOTAL, SHARD)
            .unwrap();
        drop(handles);
        report
    });
    assert_eq!(sketch_bytes(&got), sketch_bytes(&clean));
    assert!(got.degradations.range_reassignments >= 1);
}
