//! Statistical invariants of the coreset constructions — the testable
//! faces of Lemmas 2.1–2.3 and Theorem 2.4 — driven through the public
//! facade (`SessionBuilder` → `Session::coreset` / the `TableRunner`
//! harness, which itself runs every repetition through `Session::fit`).

use mctm_coreset::basis::Design;
use mctm_coreset::coordinator::experiment::{design_of, TableRunner};
use mctm_coreset::coreset::hull::{dist_to_hull, select_hull_points};
use mctm_coreset::coreset::leverage::{leverage_scores_ridged_with, sensitivity_scores};
use mctm_coreset::prelude::*;
use mctm_coreset::util::parallel::Pool;

/// One facade sketch: the coreset of `data` under (method, k, d, seed).
fn sketch(data: &Mat, method: Method, k: usize, d: usize, seed: u64) -> CoresetReport {
    SessionBuilder::new()
        .method_tag(method)
        .budget(k)
        .basis_size(d)
        .seed(seed)
        .build()
        .expect("valid test session")
        .coreset(data)
        .expect("non-empty data")
}

fn random_theta_lambda(spec: ModelSpec, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let p = Params::new(
        spec,
        (0..spec.n_params()).map(|_| 0.5 * rng.normal()).collect(),
    );
    (p.theta(), p.lambda_block().to_vec())
}

/// Lemma 2.1 (statistical form): leverage-score sampling preserves f₁
/// within small relative error on average, across parameter draws and
/// heterogeneous DGPs.
#[test]
fn f1_preserved_within_epsilon() {
    use mctm_coreset::mctm::nll_parts;
    let spec = ModelSpec::new(2, 6);
    for dgp in [Dgp::BivariateNormal, Dgp::Heteroscedastic, Dgp::NormalMixture] {
        let mut rng = Rng::new(17);
        let data = dgp.generate(4_000, &mut rng);
        let design = design_of(&data, 6);
        let mut worst: f64 = 0.0;
        let mut mean_err = 0.0;
        let trials = 12;
        for t in 0..trials {
            let (theta, lam) = random_theta_lambda(spec, 100 + t);
            let full = nll_parts(&design, &[], &theta, &lam);
            let cs = sketch(&data, Method::L2Only, 400, 6, 500 + t);
            let sub = design.select(cs.indices.as_deref().expect("batch path"));
            let part = nll_parts(&sub, &cs.weights, &theta, &lam);
            let rel = ((part.f1 - full.f1) / full.f1).abs();
            worst = worst.max(rel);
            mean_err += rel / trials as f64;
        }
        assert!(
            mean_err < 0.15,
            "{}: mean f1 relative error {mean_err}",
            dgp.name()
        );
        assert!(worst < 0.6, "{}: worst f1 error {worst}", dgp.name());
    }
}

/// The hull component guards the negative-log part: for every direction
/// ϑ, the minimum of ⟨ϑ, a'⟩ over the coreset must approximate the
/// minimum over the full data (otherwise f₃ is unbounded off-sample —
/// the failure mode Lemma 2.3 fixes).
#[test]
fn hull_preserves_min_inner_products() {
    let mut rng = Rng::new(23);
    let data = Dgp::NormalMixture.generate(3_000, &mut rng);
    let design = design_of(&data, 6);
    let dp = design.deriv_points();
    let cs = sketch(&data, Method::L2Hull, 60, 6, 24);
    assert!(cs.n_hull > 0);
    let indices = cs.indices.as_deref().expect("batch path");

    // directions: random unit vectors in basis space
    let d = design.d;
    for _ in 0..50 {
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        v.iter_mut().for_each(|x| *x /= norm);
        let full_min = (0..dp.rows)
            .map(|r| dot(dp.row(r), &v))
            .fold(f64::INFINITY, f64::min);
        let coreset_min = indices
            .iter()
            .flat_map(|&i| (0..design.j).map(move |j| (i, j)))
            .map(|(i, j)| dot(design.ad_row(i, j), &v))
            .fold(f64::INFINITY, f64::min);
        // coreset min can only be ≥ full min; must not be far off
        let spread = {
            let max = (0..dp.rows)
                .map(|r| dot(dp.row(r), &v))
                .fold(f64::NEG_INFINITY, f64::max);
            max - full_min
        };
        assert!(
            coreset_min - full_min <= 0.35 * spread + 1e-9,
            "direction min gap {} of spread {spread}",
            coreset_min - full_min
        );
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Uniform-sampling weights are exactly n/k and importance weights are
/// inverse-probability — total weight unbiased for n.
#[test]
fn weights_are_unbiased() {
    let mut rng = Rng::new(29);
    let data = Dgp::Circular.generate(2_000, &mut rng);
    for method in [Method::L2Only, Method::RidgeLss, Method::RootL2] {
        let mut mean_total = 0.0;
        let reps = 40;
        for rep in 0..reps {
            let cs = sketch(&data, method, 50, 5, 3_000 + rep);
            mean_total += cs.total_weight / reps as f64;
        }
        let rel = (mean_total - 2_000.0).abs() / 2_000.0;
        assert!(rel < 0.2, "{}: E[total weight] off by {rel}", method.name());
    }
}

/// The selected hull points of Algorithm 2 cover the point cloud: after
/// selection, the farthest remaining point is close to conv(selected).
#[test]
fn hull_selection_coverage_decreases() {
    let mut rng = Rng::new(31);
    let data = Dgp::SkewT.generate(1_500, &mut rng);
    let design: Design = design_of(&data, 5);
    let dp = design.deriv_points();
    let few = select_hull_points(&dp, 4, &mut rng);
    let many = select_hull_points(&dp, 24, &mut rng);
    let coverage = |hull: &[usize]| -> f64 {
        (0..dp.rows)
            .step_by(7)
            .map(|r| dist_to_hull(&dp, hull, dp.row(r)))
            .fold(0.0, f64::max)
    };
    let c_few = coverage(&few);
    let c_many = coverage(&many);
    assert!(
        c_many <= c_few + 1e-12,
        "coverage must improve: {c_many} vs {c_few}"
    );
}

/// The sampling probabilities feeding Algorithm 1 must not depend on
/// scheduling: the whole sensitivity pipeline (basis build → Gram →
/// Cholesky → scoring) is bit-reproducible run to run, and the leverage
/// kernel is bit-identical between the serial reference and any worker
/// count — at a realistic DGP scale that spans several row shards.
#[test]
fn sensitivity_pipeline_deterministic_across_threads() {
    let mut rng = Rng::new(53);
    let data = Dgp::NormalMixture.generate(5_000, &mut rng);
    let design = design_of(&data, 6);

    let s1 = sensitivity_scores(&design).unwrap();
    let s2 = sensitivity_scores(&design).unwrap();
    assert_eq!(s1.len(), s2.len());
    for (a, b) in s1.iter().zip(&s2) {
        assert_eq!(a.to_bits(), b.to_bits(), "sensitivity scores not reproducible");
    }

    let stacked = design.stacked();
    let reference = leverage_scores_ridged_with(&stacked, 0.0, &Pool::new(1)).unwrap();
    for t in [2usize, 4, 8] {
        let got = leverage_scores_ridged_with(&stacked, 0.0, &Pool::new(t)).unwrap();
        for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "leverage row {i} differs between 1 and {t} threads"
            );
        }
    }
}

/// ISSUE 2 satellite — Lemma 2.3's failure mode on a heavy-tailed DGP
/// (CopulaComplex: Gamma(2,1) × LogNormal(0,1) marginals, a log-normal-
/// style upper tail). Min–max scaling squashes the bulk of such data
/// into a narrow band, so the negative-log part f₃ is governed by a few
/// extreme derivative rows that a plain ℓ₂ sensitivity sample has no
/// reason to keep — fits on such coresets can blow up off-sample. The
/// hull component pins exactly those rows, keeping every hull-coreset
/// fit's full-data NLL finite and competitive.
#[test]
fn l2hull_guards_nll_on_heavy_tails() {
    let mut rng = Rng::new(67);
    let data = Dgp::CopulaComplex.generate(5_000, &mut rng);
    let opts = FitOptions { max_iters: 120, ..Default::default() };
    let runner = TableRunner::new(&data, 6, opts, 19);
    let hull = runner.run(Method::L2Hull, 40, 5);
    let plain = runner.run(Method::L2Only, 40, 5);
    // the hull component must actually be exercised …
    assert!(
        hull.n_hull.iter().all(|&h| h > 0.0),
        "hull augmentation missing: {:?}",
        hull.n_hull
    );
    // … every hull-coreset fit stays finite (and sane) on the FULL data,
    // rep by rep — no silent blow-up of the negative-log part
    for (rep, lr) in hull.lr.iter().enumerate() {
        assert!(
            lr.is_finite() && *lr < 5.0,
            "hull rep {rep}: full-data LR {lr} blown up"
        );
    }
    // … and on average the guard does not lose to the plain sampler on
    // its own failure mode (the paper's 12/14-scenario margin)
    // margin 0.08 matches the triage arithmetic in fit_recovery.rs: the
    // mean-LR gap over 5 reps carries ~0.06 sampling std of its own
    let (lr_hull, lr_plain) = (mean(&hull.lr), mean(&plain.lr));
    assert!(
        lr_hull < lr_plain + 0.08,
        "l2-hull {lr_hull} should not lose clearly to l2-only {lr_plain}"
    );
}

/// ISSUE 3 — the ellipsoid methods are first-class strategies: valid
/// coresets on a heterogeneous DGP, and bit-identical for any
/// worker-pool width (the Khachiyan rounding + hull selection inside
/// run on the deterministic pool, so the sampled coreset depends only
/// on the RNG). PR 4: driven through the facade's `threads` knob.
#[test]
fn ellipsoid_methods_valid_and_thread_deterministic() {
    let mut rng = Rng::new(91);
    let data = Dgp::NormalMixture.generate(3_000, &mut rng);
    for method in [Method::Ellipsoid, Method::EllipsoidHull] {
        let cs = sketch(&data, method, 60, 6, 92);
        assert!(cs.size > 0, "{} empty", method.name());
        assert!(cs.size <= 60, "{} oversize: {}", method.name(), cs.size);
        let indices = cs.indices.as_deref().expect("batch path");
        assert_eq!(indices.len(), cs.weights.len());
        assert!(
            cs.weights.iter().all(|&w| w > 0.0 && w.is_finite()),
            "{} weights",
            method.name()
        );
        assert!(indices.iter().all(|&i| i < 3_000), "{} range", method.name());
        if method == Method::EllipsoidHull {
            assert!(cs.n_hull > 0, "ellipsoid-hull must pin hull points");
        }

        // pool-width bit-identity at threads {1, 2, 8}: same seed, same
        // coreset, to the bit — through SessionBuilder::threads
        let at_threads = |t: usize| {
            SessionBuilder::new()
                .method_tag(method)
                .budget(60)
                .basis_size(6)
                .seed(17)
                .threads(t)
                .build()
                .unwrap()
                .coreset(&data)
                .unwrap()
        };
        let reference = at_threads(1);
        for t in [2usize, 8] {
            let got = at_threads(t);
            assert_eq!(
                reference.indices,
                got.indices,
                "{} indices differ between 1 and {t} threads",
                method.name()
            );
            for (i, (a, b)) in reference.weights.iter().zip(&got.weights).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} weight {i} differs between 1 and {t} threads",
                    method.name()
                );
            }
        }
    }
}

/// ISSUE 3 — the heavy-tail NLL guard, mirrored from
/// `l2hull_guards_nll_on_heavy_tails` for the ellipsoid pair: the hull
/// component must keep every ellipsoid-hull fit finite on the full
/// data, and on average the hybrid must not lose to plain ellipsoid
/// sampling on its own failure mode. Margin 0.10 (vs 0.08 for ℓ₂):
/// the (1+ε)-MVEE scores are coarser than exact leverage, adding a
/// little sampling spread of their own.
#[test]
fn ellipsoid_hull_guards_nll_on_heavy_tails() {
    let mut rng = Rng::new(73);
    let data = Dgp::CopulaComplex.generate(5_000, &mut rng);
    let opts = FitOptions { max_iters: 120, ..Default::default() };
    let runner = TableRunner::new(&data, 6, opts, 29);
    let hull = runner.run(Method::EllipsoidHull, 40, 5);
    let plain = runner.run(Method::Ellipsoid, 40, 5);
    // the hull component must actually be exercised …
    assert!(
        hull.n_hull.iter().all(|&h| h > 0.0),
        "hull augmentation missing: {:?}",
        hull.n_hull
    );
    // … every ellipsoid-hull fit stays finite (and sane) on the FULL
    // data, rep by rep — no silent blow-up of the negative-log part
    for (rep, lr) in hull.lr.iter().enumerate() {
        assert!(
            lr.is_finite() && *lr < 5.0,
            "ellipsoid-hull rep {rep}: full-data LR {lr} blown up"
        );
    }
    // … and on average the guard does not lose to the plain sampler
    let (lr_hull, lr_plain) = (mean(&hull.lr), mean(&plain.lr));
    assert!(
        lr_hull < lr_plain + 0.10,
        "ellipsoid-hull {lr_hull} should not lose clearly to ellipsoid {lr_plain}"
    );
}

/// Theorem 2.4 (statistical form): at the FULL-data optimum-ish
/// parameters, the weighted coreset loss approximates the full loss
/// after the normalization shift.
#[test]
fn total_loss_preserved_at_reference_params() {
    use mctm_coreset::mctm::nll_parts;
    let spec = ModelSpec::new(2, 6);
    let mut rng = Rng::new(37);
    let data = Dgp::BivariateNormal.generate(5_000, &mut rng);
    let design = design_of(&data, 6);
    // reference parameters: a quick fit (so hd > 0 everywhere and both
    // log parts are exercised)
    let fitted = mctm_coreset::fit::fit_native(
        spec,
        &design,
        Vec::new(),
        &mctm_coreset::fit::FitOptions {
            max_iters: 80,
            ..Default::default()
        },
    );
    let theta = fitted.params.theta();
    let lam = fitted.params.lambda_block().to_vec();
    let full = nll_parts(&design, &[], &theta, &lam);
    // the lemmas bound |Δf| by ε·f₁ plus an additive η·n term — assert
    // exactly that normalized form
    let denom = full.f1 + 5_000.0;
    let mut errs = Vec::new();
    for trial in 0..10u64 {
        let cs = sketch(&data, Method::L2Hull, 500, 6, 7_000 + trial);
        let sub = design.select(cs.indices.as_deref().expect("batch path"));
        let part = nll_parts(&sub, &cs.weights, &theta, &lam);
        errs.push((part.total() - full.total()).abs() / denom);
    }
    let mean: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mean < 0.1, "mean (ε f1 + η n)-normalized loss error {mean}");
}
