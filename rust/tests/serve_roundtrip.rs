//! PR 7 acceptance tests for the serving layer: a background server
//! answers every query kind concurrently without error, responses are
//! deterministic and parse back to the exact bits the model computes,
//! per-endpoint metrics count requests, and error paths are HTTP
//! statuses — never worker panics.

use mctm_coreset::prelude::*;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

fn serve_model() -> (ServerHandle, FittedModel) {
    let mut rng = Rng::new(510);
    let data = Dgp::BivariateNormal.generate(900, &mut rng);
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(80)
        .basis_size(5)
        .seed(31)
        .max_iters(60)
        .build()
        .unwrap();
    let model = session.fit(&data).unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("demo", model.clone());
    let server = Server::bind("127.0.0.1:0", registry).unwrap();
    (server.spawn(), model)
}

/// Minimal HTTP/1.1 client: one request, read to EOF (the server
/// speaks `Connection: close`), return (status, body).
fn http_get(addr: SocketAddr, target: &str) -> (u16, String) {
    http_request(addr, &format!("GET {target} HTTP/1.1\r\nHost: test\r\n\r\n"))
}

fn http_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

/// Pull a numeric field out of the flat JSON the server emits.
fn json_field(body: &str, key: &str) -> f64 {
    let pat = format!("\"{key}\":");
    let at = body.find(&pat).unwrap_or_else(|| panic!("no `{key}` in {body}"));
    let rest = &body[at + pat.len()..];
    let end = rest
        .find(|c: char| c == ',' || c == '}' || c == ']')
        .unwrap_or(rest.len());
    rest[..end].parse().unwrap_or_else(|_| panic!("`{key}` not numeric in {body}"))
}

#[test]
fn serves_every_query_kind_concurrently_without_error() {
    let (handle, model) = serve_model();
    let addr = handle.addr();

    // acceptance: ≥ 4 query kinds, concurrently, all 200
    let targets = [
        "/v1/models/demo/density?y=0.5,-0.25",
        "/v1/models/demo/cdf?j=0&y=1.0",
        "/v1/models/demo/quantile?j=1&p=0.75",
        "/v1/models/demo/sample?n=5&seed=9",
        "/v1/models/demo/conditional?given=0.8&n=4&seed=11",
    ];
    let handles: Vec<_> = (0..8)
        .map(|w| {
            std::thread::spawn(move || {
                for t in &targets {
                    let (status, body) = http_get(addr, t);
                    assert_eq!(status, 200, "worker {w}: {t} -> {status}: {body}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // responses parse back to the exact bits the model computes
    let (_, body) = http_get(addr, "/v1/models/demo/cdf?j=0&y=1.0");
    let got = json_field(&body, "cdf");
    assert_eq!(got.to_bits(), model.try_cdf(0, 1.0).unwrap().to_bits());
    let (_, body) = http_get(addr, "/v1/models/demo/quantile?j=1&p=0.75");
    assert_eq!(
        json_field(&body, "quantile").to_bits(),
        model.try_quantile(1, 0.75).unwrap().to_bits()
    );
    let (_, body) = http_get(addr, "/v1/models/demo/density?y=0.5,-0.25");
    assert_eq!(
        json_field(&body, "log_density").to_bits(),
        model.log_density(&[0.5, -0.25]).to_bits()
    );

    // seeded sampling is deterministic across requests (and workers)
    let (_, s1) = http_get(addr, "/v1/models/demo/sample?n=5&seed=9");
    let (_, s2) = http_get(addr, "/v1/models/demo/sample?n=5&seed=9");
    assert_eq!(s1, s2, "same seed must return identical bytes");
    let (_, s3) = http_get(addr, "/v1/models/demo/sample?n=5&seed=10");
    assert_ne!(s1, s3, "different seed must differ");

    handle.stop();
}

#[test]
fn listing_health_and_metrics_report_server_state() {
    let (handle, _model) = serve_model();
    let addr = handle.addr();

    let (status, body) = http_get(addr, "/health");
    assert_eq!(status, 200);
    assert!(body.contains("\"models\":1"), "{body}");

    let (status, body) = http_get(addr, "/v1/models");
    assert_eq!(status, 200);
    assert!(body.contains("\"name\":\"demo\""), "{body}");
    assert!(body.contains("\"j\":2"), "{body}");

    for _ in 0..3 {
        http_get(addr, "/v1/models/demo/cdf?j=0&y=0.0");
    }
    http_get(addr, "/v1/models/demo/quantile?j=0&p=0.5");
    let (status, body) = http_get(addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(json_field(&body, "cdf") as u64, 3);
    assert_eq!(json_field(&body, "quantile") as u64, 1);

    // the live handle sees the same counters
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.cdf, 3);
    assert_eq!(snap.quantile, 1);
    assert_eq!(snap.health, 1);

    handle.stop();
}

#[test]
fn error_paths_are_http_statuses_not_panics() {
    let (handle, _model) = serve_model();
    let addr = handle.addr();

    // unknown model / endpoint / path → 404
    assert_eq!(http_get(addr, "/v1/models/nope/cdf?j=0&y=1").0, 404);
    assert_eq!(http_get(addr, "/v1/models/demo/nope").0, 404);
    assert_eq!(http_get(addr, "/nope").0, 404);

    // invalid queries → 400 with the typed message
    let (status, body) = http_get(addr, "/v1/models/demo/quantile?j=0&p=1.5");
    assert_eq!(status, 400);
    assert!(body.contains("outside [0, 1]"), "{body}");
    assert_eq!(http_get(addr, "/v1/models/demo/quantile?j=0&p=NaN").0, 400);
    assert_eq!(http_get(addr, "/v1/models/demo/cdf?j=0&y=NaN").0, 400);
    assert_eq!(http_get(addr, "/v1/models/demo/cdf?j=9&y=0.5").0, 400);
    assert_eq!(http_get(addr, "/v1/models/demo/density?y=1.0").0, 400); // J mismatch
    assert_eq!(http_get(addr, "/v1/models/demo/sample?n=0").0, 400);
    assert_eq!(http_get(addr, "/v1/models/demo/cdf?j=0").0, 400); // missing y

    // pinned edge semantics over the wire: p=0/1 and y=±inf are valid
    let (status, body) = http_get(addr, "/v1/models/demo/cdf?j=0&y=inf");
    assert_eq!(status, 200);
    assert_eq!(json_field(&body, "cdf"), 1.0);
    assert_eq!(http_get(addr, "/v1/models/demo/quantile?j=0&p=0").0, 200);
    assert_eq!(http_get(addr, "/v1/models/demo/quantile?j=0&p=1").0, 200);

    // non-GET → 405
    let (status, _) =
        http_request(addr, "POST /v1/models/demo/cdf?j=0&y=1 HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 405);

    // the server survived all of it and still answers
    assert_eq!(http_get(addr, "/health").0, 200);
    let errors = handle.metrics().snapshot().errors;
    assert!(errors >= 10, "error counter should track non-2xx responses, got {errors}");

    handle.stop();
}

#[test]
fn registry_load_dir_serves_saved_artifacts() {
    let dir = std::env::temp_dir().join("mctm_serve_dir_test");
    std::fs::create_dir_all(&dir).unwrap();
    // stale files from earlier runs would fail the count below
    for entry in std::fs::read_dir(&dir).unwrap() {
        std::fs::remove_file(entry.unwrap().path()).unwrap();
    }

    let mut rng = Rng::new(512);
    let data = Dgp::BivariateNormal.generate(700, &mut rng);
    let session = SessionBuilder::new()
        .budget(60)
        .basis_size(5)
        .seed(5)
        .max_iters(50)
        .build()
        .unwrap();
    let model = session.fit(&data).unwrap();
    model.save(&dir.join("alpha.mctm")).unwrap();
    model.save(&dir.join("beta.mctm")).unwrap();
    std::fs::write(dir.join("ignored.txt"), "not an artifact").unwrap();

    let registry = Arc::new(ModelRegistry::new());
    assert_eq!(registry.load_dir(&dir).unwrap(), 2);
    assert_eq!(registry.names(), vec!["alpha".to_string(), "beta".to_string()]);

    let handle = Server::bind("127.0.0.1:0", registry).unwrap().spawn();
    let (status, body) = http_get(handle.addr(), "/v1/models");
    assert_eq!(status, 200);
    assert!(body.contains("alpha") && body.contains("beta"), "{body}");
    let (status, _) = http_get(handle.addr(), "/v1/models/alpha/quantile?j=0&p=0.5");
    assert_eq!(status, 200);
    handle.stop();

    // a corrupt artifact in the directory is a typed load error
    std::fs::write(dir.join("bad.mctm"), b"mctm-artifact v1 model\ngarbage\n").unwrap();
    let fresh = ModelRegistry::new();
    assert!(matches!(fresh.load_dir(&dir), Err(ApiError::Artifact(_))));
}
