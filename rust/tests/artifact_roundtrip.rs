//! PR 7 acceptance tests for the artifact layer: byte-identical
//! save → load → save round-trips, bitwise-equal queries from a loaded
//! model (single- and multi-threaded), `Session::refit` reproducing the
//! direct fit from a persisted sketch on both ingestion paths, and
//! corruption always surfacing as a typed error — never a panic.

use mctm_coreset::prelude::*;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("mctm_artifact_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn small_session() -> Session {
    SessionBuilder::new()
        .method("l2-hull")
        .budget(80)
        .basis_size(5)
        .seed(19)
        .max_iters(60)
        .build()
        .unwrap()
}

fn small_data() -> Mat {
    let mut rng = Rng::new(401);
    Dgp::BivariateNormal.generate(900, &mut rng)
}

#[test]
fn model_save_load_save_is_byte_identical() {
    let model = small_session().fit(&small_data()).unwrap();
    let bytes1 = Artifact::Model(model.to_artifact()).to_bytes();
    let reparsed = Artifact::from_bytes(&bytes1).unwrap();
    assert_eq!(reparsed.to_bytes(), bytes1, "save(load(save(m))) != save(m)");

    // and through the filesystem
    let p1 = temp_path("model_a.mctm");
    let p2 = temp_path("model_b.mctm");
    model.save(&p1).unwrap();
    let loaded = FittedModel::load(&p1).unwrap();
    loaded.save(&p2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "on-disk round trip is not byte-identical"
    );
}

#[test]
fn sketch_save_load_save_is_byte_identical() {
    let report = small_session().coreset(&small_data()).unwrap();
    let bytes1 = Artifact::Sketch(report.to_artifact()).to_bytes();
    let reparsed = Artifact::from_bytes(&bytes1).unwrap();
    assert_eq!(reparsed.to_bytes(), bytes1);

    let p1 = temp_path("sketch_a.mctm");
    let p2 = temp_path("sketch_b.mctm");
    report.save(&p1).unwrap();
    let loaded = CoresetReport::load(&p1).unwrap();
    loaded.save(&p2).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
}

#[test]
fn same_seed_same_bytes_across_runs() {
    // the artifact deliberately excludes wall-clock fields, so two
    // independent same-seed runs persist identical bytes
    let data = small_data();
    let m1 = small_session().fit(&data).unwrap();
    let m2 = small_session().fit(&data).unwrap();
    assert_eq!(
        Artifact::Model(m1.to_artifact()).to_bytes(),
        Artifact::Model(m2.to_artifact()).to_bytes()
    );
    let s1 = small_session().coreset(&data).unwrap();
    let s2 = small_session().coreset(&data).unwrap();
    assert_eq!(
        Artifact::Sketch(s1.to_artifact()).to_bytes(),
        Artifact::Sketch(s2.to_artifact()).to_bytes()
    );
}

#[test]
fn loaded_model_queries_are_bitwise_identical() {
    let model = small_session().fit(&small_data()).unwrap();
    let p = temp_path("model_queries.mctm");
    model.save(&p).unwrap();
    let loaded = FittedModel::load(&p).unwrap();

    assert_eq!(loaded.params().x, model.params().x);
    let probes = [[-1.3, 0.4], [0.0, 0.0], [2.1, -0.7], [0.33, 1.9]];
    for y in &probes {
        assert_eq!(
            loaded.log_density(y).to_bits(),
            model.log_density(y).to_bits(),
            "log-density differs at {y:?}"
        );
    }
    for j in 0..2 {
        for &y in &[-2.0, -0.5, 0.0, 1.5] {
            assert_eq!(
                loaded.marginal_cdf(j, y).to_bits(),
                model.marginal_cdf(j, y).to_bits()
            );
        }
        for &p in &[0.05, 0.5, 0.95] {
            assert_eq!(
                loaded.marginal_quantile(j, p).to_bits(),
                model.marginal_quantile(j, p).to_bits()
            );
        }
    }
    // sampling with the same caller-owned RNG draws identical bits
    let mut r1 = Rng::new(777);
    let mut r2 = Rng::new(777);
    let d1 = model.sample(50, &mut r1);
    let d2 = loaded.sample(50, &mut r2);
    assert_eq!(d1.data, d2.data);
}

#[test]
fn loaded_model_is_bitwise_identical_across_thread_counts() {
    // acceptance: queries on the loaded model are identical whether the
    // process serves them from 1 thread or 8 concurrently
    let model = small_session().fit(&small_data()).unwrap();
    let p = temp_path("model_threads.mctm");
    model.save(&p).unwrap();
    let loaded = Arc::new(FittedModel::load(&p).unwrap());

    let reference: Vec<u64> = (0..32)
        .map(|i| {
            let t = i as f64 / 32.0;
            let y = [-2.0 + 4.0 * t, 2.0 - 4.0 * t];
            loaded.log_density(&y).to_bits()
                ^ loaded.marginal_cdf(0, y[0]).to_bits().rotate_left(1)
                ^ loaded
                    .marginal_quantile(1, 0.05 + 0.9 * t)
                    .to_bits()
                    .rotate_left(2)
        })
        .collect();

    let handles: Vec<_> = (0..8)
        .map(|_| {
            let m = Arc::clone(&loaded);
            let expect = reference.clone();
            std::thread::spawn(move || {
                for (i, &want) in expect.iter().enumerate() {
                    let t = i as f64 / 32.0;
                    let y = [-2.0 + 4.0 * t, 2.0 - 4.0 * t];
                    let got = m.log_density(&y).to_bits()
                        ^ m.marginal_cdf(0, y[0]).to_bits().rotate_left(1)
                        ^ m.marginal_quantile(1, 0.05 + 0.9 * t).to_bits().rotate_left(2);
                    assert_eq!(got, want, "thread-side query diverged at probe {i}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn refit_from_persisted_batch_sketch_reproduces_direct_fit() {
    // acceptance: Session::refit from a persisted sketch reproduces the
    // direct-fit parameters bit-for-bit (the sketch carries the
    // full-data scaler, so the sub-design rebuilds identically)
    let data = small_data();
    let session = small_session();
    let direct = session.fit(&data).unwrap();

    let p = temp_path("refit_batch.mctm");
    session.coreset(&data).unwrap().save(&p).unwrap();
    let sketch = CoresetReport::load(&p).unwrap();
    let refit = session.refit(&sketch).unwrap();

    assert_eq!(refit.params().x, direct.params().x, "refit ϑ diverged from direct fit");
    assert_eq!(
        refit.diagnostics().fit_nll.to_bits(),
        direct.diagnostics().fit_nll.to_bits()
    );
    // and the refitted model answers queries identically
    assert_eq!(
        refit.marginal_quantile(0, 0.5).to_bits(),
        direct.marginal_quantile(0, 0.5).to_bits()
    );
}

#[test]
fn refit_from_persisted_stream_sketch_reproduces_direct_fit() {
    let mut rng = Rng::new(402);
    let data = Dgp::NormalMixture.generate(6_000, &mut rng);
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(60)
        .basis_size(5)
        .seed(23)
        .max_iters(60)
        .build()
        .unwrap();
    let direct = session.fit(MatShards::new(data.clone(), 1_500)).unwrap();

    let p = temp_path("refit_stream.mctm");
    session
        .coreset(MatShards::new(data.clone(), 1_500))
        .unwrap()
        .save(&p)
        .unwrap();
    let sketch = CoresetReport::load(&p).unwrap();
    assert!(sketch.scaler.is_none(), "stream sketches carry no full-data scaler");
    let refit = session.refit(&sketch).unwrap();
    assert_eq!(refit.params().x, direct.params().x);
}

#[test]
fn refit_warm_converges_to_a_model_quickly() {
    let data = small_data();
    let session = small_session();
    let direct = session.fit(&data).unwrap();
    let sketch = session.coreset(&data).unwrap();

    // warm-start from the direct optimum: the optimizer should stop in
    // (far) fewer iterations than the cold refit and land at the same
    // solution neighborhood
    let warm = session.refit_warm(&sketch, direct.params()).unwrap();
    assert!(
        warm.diagnostics().fit_iters <= direct.diagnostics().fit_iters,
        "warm start took {} iters, cold took {}",
        warm.diagnostics().fit_iters,
        direct.diagnostics().fit_iters
    );
    assert!((warm.diagnostics().fit_nll - direct.diagnostics().fit_nll).abs() < 1e-6);

    // shape-mismatched warm start is a typed error
    let other = SessionBuilder::new().basis_size(7).budget(80).seed(19).build().unwrap();
    assert!(matches!(
        other.refit_warm(&sketch, direct.params()).unwrap_err(),
        ApiError::Query(_)
    ));
}

#[test]
fn corrupted_and_truncated_artifacts_are_typed_errors() {
    let model = small_session().fit(&small_data()).unwrap();
    let p = temp_path("corrupt_src.mctm");
    model.save(&p).unwrap();
    let good = std::fs::read(&p).unwrap();

    // truncation at several prefixes: typed error, never a panic
    for frac in [0, 1, good.len() / 4, good.len() / 2, good.len() - 2] {
        let p_trunc = temp_path("corrupt_trunc.mctm");
        std::fs::write(&p_trunc, &good[..frac]).unwrap();
        assert!(
            matches!(FittedModel::load(&p_trunc), Err(ApiError::Artifact(_))),
            "truncation at {frac} bytes must be a typed error"
        );
    }

    // single bit flip in the middle: checksum catches it
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x01;
    let p_flip = temp_path("corrupt_flip.mctm");
    std::fs::write(&p_flip, &flipped).unwrap();
    assert!(matches!(FittedModel::load(&p_flip), Err(ApiError::Artifact(_))));

    // kind confusion: a sketch is not a model and vice versa
    let p_sketch = temp_path("corrupt_kind.mctm");
    small_session().coreset(&small_data()).unwrap().save(&p_sketch).unwrap();
    let err = FittedModel::load(&p_sketch).unwrap_err();
    assert!(
        format!("{err}").contains("sketch"),
        "kind-confusion error should name the actual kind: {err}"
    );
    assert!(matches!(CoresetReport::load(&p), Err(ApiError::Artifact(_))));

    // missing file names the path
    let missing = temp_path("does_not_exist.mctm");
    let err = FittedModel::load(&missing).unwrap_err();
    assert!(format!("{err}").contains("does_not_exist"));
}
