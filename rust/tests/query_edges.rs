//! PR 7 bugfix pins for the query surface's edge semantics:
//! `try_quantile` / `try_cdf` turn garbage levels into typed errors,
//! p = 0 / p = 1 and y = ±∞ have exact documented answers, and the
//! legacy panicking contracts of `marginal_quantile` stay intact.

use mctm_coreset::prelude::*;

fn fitted() -> FittedModel {
    let mut rng = Rng::new(614);
    let data = Dgp::BivariateNormal.generate(900, &mut rng);
    SessionBuilder::new()
        .budget(80)
        .basis_size(5)
        .seed(47)
        .max_iters(60)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap()
}

#[test]
fn try_quantile_rejects_non_finite_and_out_of_range_levels() {
    let m = fitted();
    for bad in [f64::NAN, -0.1, 1.0001, f64::INFINITY, f64::NEG_INFINITY, -0.0 - f64::EPSILON] {
        let err = m.try_quantile(0, bad).unwrap_err();
        assert!(
            matches!(err, ApiError::Query(_)),
            "p = {bad} should be a typed Query error, got {err:?}"
        );
    }
    // out-of-range margin is a typed error too, checked before p
    assert!(matches!(m.try_quantile(7, 0.5), Err(ApiError::Query(_))));
    assert!(matches!(m.try_quantile(7, f64::NAN), Err(ApiError::Query(_))));
}

#[test]
fn try_quantile_pins_the_support_edges_at_p_0_and_1() {
    let m = fitted();
    for j in 0..2 {
        let lo = m.try_quantile(j, 0.0).unwrap();
        let hi = m.try_quantile(j, 1.0).unwrap();
        // documented clamp: exactly the unscaled endpoints of the
        // transformation's axis (~ε/(1−2ε) beyond the data min/max)
        assert_eq!(lo.to_bits(), m.scaler().unscale(j, 0.0).to_bits());
        assert_eq!(hi.to_bits(), m.scaler().unscale(j, 1.0).to_bits());
        assert!(lo.is_finite() && hi.is_finite());
        // continuity: the open-interval quantiles saturate toward the
        // pinned edges (extreme p may hit them exactly), never beyond
        assert!(lo <= m.try_quantile(j, 1e-9).unwrap());
        assert!(hi >= m.try_quantile(j, 1.0 - 1e-9).unwrap());
        assert!(lo < m.try_quantile(j, 0.5).unwrap());
        assert!(hi > m.try_quantile(j, 0.5).unwrap());
    }
}

#[test]
fn try_quantile_agrees_with_marginal_quantile_inside_the_open_interval() {
    let m = fitted();
    for &p in &[1e-6, 0.05, 0.25, 0.5, 0.9, 1.0 - 1e-9] {
        for j in 0..2 {
            assert_eq!(
                m.try_quantile(j, p).unwrap().to_bits(),
                m.marginal_quantile(j, p).to_bits()
            );
        }
    }
}

#[test]
fn marginal_quantile_keeps_its_panicking_contract() {
    // existing callers rely on the assert; the typed surface is opt-in
    let m = fitted();
    for bad in [0.0, 1.0, -0.5, 1.5, f64::NAN] {
        let m2 = m.clone();
        assert!(
            std::panic::catch_unwind(move || m2.marginal_quantile(0, bad)).is_err(),
            "marginal_quantile({bad}) should panic"
        );
    }
}

#[test]
fn cdf_at_infinities_is_exactly_zero_and_one() {
    let m = fitted();
    for j in 0..2 {
        assert_eq!(m.marginal_cdf(j, f64::INFINITY).to_bits(), 1.0f64.to_bits());
        assert_eq!(m.marginal_cdf(j, f64::NEG_INFINITY).to_bits(), 0.0f64.to_bits());
        assert_eq!(m.try_cdf(j, f64::INFINITY).unwrap(), 1.0);
        assert_eq!(m.try_cdf(j, f64::NEG_INFINITY).unwrap(), 0.0);
        // and the CDF stays monotone into the far tails
        assert!(m.marginal_cdf(j, 1e300) <= 1.0);
        assert!(m.marginal_cdf(j, -1e300) >= 0.0);
        assert!(m.marginal_cdf(j, 1e300) >= m.marginal_cdf(j, 0.0));
    }
}

#[test]
fn try_cdf_rejects_nan_and_bad_margins() {
    let m = fitted();
    assert!(matches!(m.try_cdf(0, f64::NAN), Err(ApiError::Query(_))));
    assert!(matches!(m.try_cdf(9, 0.5), Err(ApiError::Query(_))));
    // the panicking surface propagates NaN instead (documented)
    assert!(m.marginal_cdf(0, f64::NAN).is_nan());
}

#[test]
fn quantile_cdf_edges_survive_persistence() {
    // edge semantics must be a property of the model, not of the
    // process that fitted it
    let m = fitted();
    let path = std::env::temp_dir().join("mctm_query_edges.mctm");
    m.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    assert_eq!(
        loaded.try_quantile(0, 0.0).unwrap().to_bits(),
        m.try_quantile(0, 0.0).unwrap().to_bits()
    );
    assert_eq!(loaded.try_cdf(1, f64::INFINITY).unwrap(), 1.0);
    assert!(matches!(loaded.try_quantile(0, 2.0), Err(ApiError::Query(_))));
}
