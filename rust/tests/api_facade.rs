//! PR 4 acceptance tests for the facade: builder validation, automatic
//! batch-vs-streaming dispatch and their statistical equivalence on a
//! fixed seed, and concurrent read-side serving from one `FittedModel`.

use mctm_coreset::prelude::*;

#[test]
fn builder_validation_is_typed_and_lists_methods() {
    // unknown method → error listing every registry name
    let err = SessionBuilder::new().method("not-a-method").build().unwrap_err();
    let msg = format!("{err}");
    for m in Method::all() {
        assert!(msg.contains(m.name()), "error should list {}: {msg}", m.name());
    }
    assert!(matches!(err, ApiError::UnknownMethod { .. }));

    // zero budget and zero threads are rejected up front
    for err in [
        SessionBuilder::new().budget(0).build().unwrap_err(),
        SessionBuilder::new().threads(0).build().unwrap_err(),
        SessionBuilder::new().consumers(0).build().unwrap_err(),
        SessionBuilder::new().buffer_factor(0).build().unwrap_err(),
    ] {
        match err {
            ApiError::Config { key, reason } => {
                assert!(!key.is_empty() && !reason.is_empty());
            }
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    // every registered name builds
    for m in Method::all() {
        assert!(SessionBuilder::new().method(m.name()).build().is_ok());
    }
}

#[test]
fn batch_and_streaming_agree_on_a_fixed_seed() {
    // one distribution, one seed recipe, two ingestion paths through
    // the SAME facade: a materialized Mat (batch) and shards of it
    // (Merge & Reduce). The paths use different estimators, so exact
    // equality is not expected — but both must be deterministic, carry
    // the correct diagnostics, and land within the established quality
    // envelope of the full fit.
    let total = 12_000;
    let mut rng = Rng::new(61);
    let data = Dgp::BivariateNormal.generate(total, &mut rng);
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(100)
        .basis_size(6)
        .seed(7)
        .max_iters(150)
        .build()
        .unwrap();

    let batch = session.fit(&data).unwrap();
    let streamed = session.fit(MatShards::new(data.clone(), 2_000)).unwrap();

    // dispatch happened automatically and is visible in diagnostics
    assert!(batch.diagnostics().coreset.stream.is_none());
    let sstats = streamed.diagnostics().coreset.stream.clone().expect("stream path");
    assert_eq!(sstats.n_seen, total);
    assert_eq!(sstats.n_shards, 6);

    // fixed seed ⇒ both paths reproduce bit-for-bit on a rerun
    let batch2 = session.fit(&data).unwrap();
    assert_eq!(batch.params().x, batch2.params().x);
    let streamed2 = session.fit(MatShards::new(data.clone(), 2_000)).unwrap();
    assert_eq!(streamed.params().x, streamed2.params().x);

    // statistical equivalence: both approximate the full fit on the
    // same evaluation sample
    let full = SessionBuilder::new()
        .budget(total)
        .basis_size(6)
        .seed(7)
        .max_iters(150)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap();
    let full_nll = full.diagnostics().fit_nll;
    let lr_batch = loglik_ratio(batch.nll(&data), full_nll, total, 2);
    let lr_stream = loglik_ratio(streamed.nll(&data), full_nll, total, 2);
    assert!(lr_batch < 1.4, "batch LR {lr_batch}");
    assert!(lr_stream < 1.9, "streamed LR {lr_stream}");
    // and their median queries agree within a modest band
    let (mb, ms) = (batch.marginal_quantile(0, 0.5), streamed.marginal_quantile(0, 0.5));
    assert!((mb - ms).abs() < 0.5, "medians diverge: {mb} vs {ms}");
}

#[test]
fn one_fitted_model_serves_concurrent_queries() {
    // FittedModel is Send + Sync by construction: hit one instance from
    // many threads with the whole query surface and check the answers
    // are identical to the single-threaded ones.
    let mut rng = Rng::new(88);
    let data = Dgp::BivariateNormal.generate(4_000, &mut rng);
    let model = SessionBuilder::new()
        .method("l2-hull")
        .budget(200)
        .basis_size(6)
        .seed(5)
        .max_iters(120)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap();

    // single-threaded reference answers
    let grid: Vec<f64> = (0..20).map(|i| -2.0 + 0.2 * i as f64).collect();
    let ref_logd: Vec<f64> = grid.iter().map(|&y| model.log_density(&[y, 0.3])).collect();
    let ref_cdf: Vec<f64> = grid.iter().map(|&y| model.marginal_cdf(1, y)).collect();
    let ref_q = model.marginal_quantile(0, 0.75);

    let model_ref = &model;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..8usize {
            let grid = grid.clone();
            let ref_logd = ref_logd.clone();
            let ref_cdf = ref_cdf.clone();
            handles.push(s.spawn(move || {
                // every thread owns its RNG; the model is shared read-only
                let mut rng = Rng::new(1000 + t as u64);
                for (i, &y) in grid.iter().enumerate() {
                    let ld = model_ref.log_density(&[y, 0.3]);
                    assert_eq!(ld.to_bits(), ref_logd[i].to_bits(), "thread {t} log_density");
                    let c = model_ref.marginal_cdf(1, y);
                    assert_eq!(c.to_bits(), ref_cdf[i].to_bits(), "thread {t} cdf");
                }
                let q = model_ref.marginal_quantile(0, 0.75);
                assert_eq!(q.to_bits(), ref_q.to_bits(), "thread {t} quantile");
                let draws = model_ref.sample_conditional(&[0.5], 50, &mut rng);
                assert_eq!((draws.rows, draws.cols), (50, 2));
                assert!(draws.data.iter().all(|v| v.is_finite()));
            }));
        }
        for h in handles {
            h.join().expect("query thread panicked");
        }
    });
}

#[test]
fn query_surface_is_coherent() {
    // CDF/quantile/density/sampling tell one consistent story about
    // the same fitted distribution
    let mut rng = Rng::new(14);
    let data = Dgp::Heteroscedastic.generate(3_000, &mut rng);
    let model = SessionBuilder::new()
        .budget(3_000) // identity coreset — exact fit, no sampling noise
        .basis_size(6)
        .seed(2)
        .max_iters(150)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap();

    for j in 0..2 {
        // CDF is monotone over the data range
        let (lo, hi) = (model.marginal_quantile(j, 0.05), model.marginal_quantile(j, 0.95));
        assert!(lo < hi, "margin {j}: q05 {lo} !< q95 {hi}");
        let mut prev = 0.0;
        for step in 0..=20 {
            let y = lo + (hi - lo) * step as f64 / 20.0;
            let c = model.marginal_cdf(j, y);
            assert!(c >= prev - 1e-12, "margin {j}: CDF not monotone at {y}");
            prev = c;
        }
        // density integrates to ≈ the CDF mass over [lo, hi]
        let m = 400;
        let mut integral = 0.0;
        for i in 0..m {
            let y = lo + (hi - lo) * (i as f64 + 0.5) / m as f64;
            integral += model.marginal_density(j, y) * (hi - lo) / m as f64;
        }
        let mass = model.marginal_cdf(j, hi) - model.marginal_cdf(j, lo);
        assert!(
            (integral - mass).abs() < 0.03,
            "margin {j}: ∫f = {integral} vs ΔF = {mass}"
        );
    }

    // log_density agrees with density where the latter doesn't underflow
    let y = [data.at(10, 0), data.at(10, 1)];
    let (ld, d) = (model.log_density(&y), model.density(&y));
    assert!(d > 0.0 && (d.ln() - ld).abs() < 1e-9);

    // empirical CDF of model samples matches the model CDF (margin 0)
    let draws = model.sample(3_000, &mut rng);
    let y0 = model.marginal_quantile(0, 0.3);
    let emp = draws_below(&draws, 0, y0) / 3_000.0;
    assert!((emp - 0.3).abs() < 0.05, "empirical CDF {emp} vs 0.3");
}

fn draws_below(m: &Mat, col: usize, y: f64) -> f64 {
    (0..m.rows).filter(|&r| m.at(r, col) < y).count() as f64
}
