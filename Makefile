# Build / verify entry points. The Rust package lives under rust/; the
# AOT artifact builder (JAX/Pallas) under python/compile/.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test check ci fmt clippy doc example bench-compile bench-quick bench-perf bench-json serve-smoke store-smoke dist-smoke artifacts

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

fmt:
	$(CARGO) fmt --manifest-path $(MANIFEST) -- --check

clippy:
	$(CARGO) clippy --manifest-path $(MANIFEST) --all-targets -- -D warnings

# Rustdoc gate for the public API (broken intra-doc links etc. fail).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# The facade walkthrough: builder → session → fit → queries.
example:
	$(CARGO) run --release --manifest-path $(MANIFEST) --example quickstart

# Compile gate for every bench target (they are plain main()s, so a
# bitrotted bench only surfaces at `cargo bench` time without this).
bench-compile:
	$(CARGO) bench --no-run --manifest-path $(MANIFEST)

# The tier-1 gate: formatting, lints as errors, docs, full test suite.
check: fmt clippy doc test

# What .github/workflows/ci.yml runs: fmt --check, build, tests, the
# rustdoc gate, the bench compile gate, and the lib/bin clippy pass
# (the all-targets lint stays in `make check` for local use).
# The clippy pass also enforces the robustness gate: non-test library
# code carries `warn(clippy::unwrap_used, clippy::expect_used)` as a
# crate attribute in rust/src/lib.rs, so with -D warnings any new
# unwrap/expect outside tests fails CI unless explicitly #[allow]ed
# with a justification.
ci: fmt build test doc bench-compile serve-smoke store-smoke dist-smoke
	$(CARGO) clippy --manifest-path $(MANIFEST) -- -D warnings

# End-to-end persist & serve smoke (PR 7): save a model + sketch
# artifact, verify same-seed byte-identical re-save, start mctm-serve
# on an ephemeral port, and hit every query endpoint plus the pinned
# edge cases over real HTTP. Reuses the release binaries from `build`.
serve-smoke: build
	bash scripts/serve_smoke.sh

# Out-of-core ingestion smoke (PR 9): deterministic CSV -> `import` ->
# store-backed fit byte-identical to the in-memory fit (artifact cmp),
# plus the `store:` streaming registry path.
store-smoke: build
	bash scripts/store_smoke.sh

# Distributed sketching smoke (PR 10): two local workers, `dist-fit`
# artifacts byte-identical to the single-process `stream` run, and a
# worker killed mid-run recovering to the exact same bytes.
dist-smoke: build
	bash scripts/dist_smoke.sh

# Hot-path microbench at the smallest scale (CI smoke): serial vs
# parallel medians for basis build, leverage, gram, nll_grad.
bench-quick:
	MCTM_BENCH_SCALE=fast $(CARGO) bench --manifest-path $(MANIFEST) --bench perf_hotpath

# Full-scale hot-path bench (feeds EXPERIMENTS.md §Perf).
bench-perf:
	$(CARGO) bench --manifest-path $(MANIFEST) --bench perf_hotpath

# Machine-readable per-kernel medians (PR 8): scalar-vs-SIMD backend and
# thread sweeps for nll_grad, the conditional panel path and serving
# qps, dumped to BENCH_PR8.json at the repo root. CI runs this at
# MCTM_BENCH_SCALE=fast as a compile-and-run smoke.
bench-json:
	MCTM_BENCH_JSON=BENCH_PR8.json $(CARGO) bench --manifest-path $(MANIFEST) --bench perf_hotpath

# AOT-compile the XLA/Pallas artifacts consumed by the PJRT runtime.
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
