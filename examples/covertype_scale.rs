//! Large-scale scenario (paper §3.2): MCTM density estimation over a
//! 10-variable terrain dataset where full-data fitting is the paper's
//! motivating pain point. Shows the size-vs-accuracy trade-off across
//! coreset sizes, native backend.
//!
//! Run: cargo run --release --example covertype_scale [-- n=200000]

use mctm_coreset::coordinator::experiment::{summarize, TableRunner};
use mctm_coreset::coreset::Method;
use mctm_coreset::data::covertype;
use mctm_coreset::fit::FitOptions;
use mctm_coreset::util::mean;
use mctm_coreset::util::report::Table;
use mctm_coreset::util::rng::Rng;

fn main() {
    let n: usize = std::env::args()
        .find_map(|a| a.strip_prefix("n=").map(|v| v.parse().unwrap()))
        .unwrap_or(50_000);
    let mut rng = Rng::new(7);
    let data = covertype::generate(n, &mut rng);
    println!("terrain workload: {} rows × {} vars", data.rows, data.cols);

    let opts = FitOptions { max_iters: 200, ..Default::default() };
    let runner = TableRunner::new(&data, 7, opts, 54);
    println!(
        "full fit: nll={:.1} in {:.1}s ({} iters)",
        runner.full.fit.nll, runner.full.seconds, runner.full.fit.iters
    );

    let mut table = Table::new(
        "covertype scale-up: error vs coreset size",
        &["k", "method", "theta L2", "lambda err", "LR", "impr(%)", "time(s)"],
    );
    for k in [50, 200, 500] {
        let hull = runner.run(Method::L2Hull, k, 3);
        let unif = runner.run(Method::Uniform, k, 3);
        let speedup = runner.full.seconds / mean(&hull.total_secs()).max(1e-9);
        for s in [&hull, &unif] {
            let mut row = vec![format!("{k}")];
            row.extend(summarize(s, &unif));
            table.row(row);
        }
        println!("k={k}: l2-hull end-to-end speedup vs full fit ≈ {speedup:.0}×");
    }
    table.emit(None);
}
