//! Financial scenario (paper §3.2 / §E.2.2): joint modeling of 10
//! volatility-clustered, heavy-tailed stock-return series with a
//! Gaussian-copula MCTM, fitted from a coreset. Reports the fitted
//! dependence structure (λ-implied marginal variances) and tail
//! quantiles of the fitted margins — the quantities a risk system
//! consumes.
//!
//! Run: cargo run --release --example equity_risk

use mctm_coreset::coordinator::experiment::{design_of, full_fit};
use mctm_coreset::coreset::{build_coreset, Method};
use mctm_coreset::data::equity;
use mctm_coreset::fit::{fit_native, FitOptions};
use mctm_coreset::mctm::density::marginal_sigmas;
use mctm_coreset::mctm::{lambda_error, ModelSpec};
use mctm_coreset::util::rng::Rng;

fn main() {
    let (n_days, n_stocks, k) = (10_000, 10, 300);
    let mut rng = Rng::new(1985);
    let returns = equity::generate(n_days, n_stocks, &mut rng);
    println!("{n_days} trading days × {n_stocks} stocks (~40y of daily returns)");

    let design = design_of(&returns, 7);
    let spec = ModelSpec::new(n_stocks, 7);
    let opts = FitOptions { max_iters: 200, ..Default::default() };

    println!("fitting full data (this is the slow path the paper attacks)...");
    let full = full_fit(&design, spec, &opts);
    println!("  full: nll={:.1} in {:.1}s", full.fit.nll, full.seconds);

    let cs = build_coreset(&design, Method::L2Hull, k, &mut rng);
    let sub = design.select(&cs.indices);
    let fit = fit_native(spec, &sub, cs.weights.clone(), &opts);
    println!(
        "  coreset (k={}): nll={:.1}, λ-error vs full = {:.3}",
        cs.len(),
        fit.nll,
        lambda_error(&fit.params, &full.fit.params)
    );

    // implied dependence: σ_j of h̃_j(Y) under the fitted copula — a
    // proxy for how strongly stock j loads on the common structure
    let sig_full = marginal_sigmas(&full.fit.params);
    let sig_core = marginal_sigmas(&fit.params);
    println!("\nimplied marginal sigmas (full vs coreset):");
    for s in 0..n_stocks {
        println!("  stock {s:>2}: {:.3} vs {:.3}", sig_full[s], sig_core[s]);
    }
    let max_rel = sig_full
        .iter()
        .zip(&sig_core)
        .map(|(a, b)| ((a - b) / a).abs())
        .fold(0.0f64, f64::max);
    println!("max relative sigma deviation: {:.1}%", 100.0 * max_rel);

    // tail behaviour: 1% left-tail quantile of each fitted margin via
    // inverse transform on a y-grid (risk = VaR-like number)
    println!("\n1% left-tail (VaR-like) of margin 0:");
    for (label, params) in [("full", &full.fit.params), ("coreset", &fit.params)] {
        let mut lo = design.scaler.mins[0];
        let hi = design.scaler.maxs[0];
        // integrate the marginal density to the 1% point
        let m = 4000;
        let step = (hi - lo) / m as f64;
        let mut acc = 0.0;
        let mut var99 = lo;
        for i in 0..m {
            let y = lo + step * (i as f64 + 0.5);
            acc += mctm_coreset::mctm::marginal_density(params, &design.scaler, 0, y) * step;
            if acc >= 0.01 {
                var99 = y;
                break;
            }
        }
        println!("  {label:>7}: {var99:+.4} (daily return)");
        lo = var99; // silence unused warning paranoia
        let _ = lo;
    }
    println!("\nequity_risk OK");
}
