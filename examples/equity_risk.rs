//! Financial scenario (paper §3.2 / §E.2.2): joint modeling of 10
//! volatility-clustered, heavy-tailed stock-return series with a
//! Gaussian-copula MCTM, fitted from a coreset through the facade.
//! Reports the fitted dependence structure (λ-implied marginal
//! variances) and tail quantiles of the fitted margins — the
//! quantities a risk system consumes, served straight off the
//! `FittedModel` query surface.
//!
//! Run: cargo run --release --example equity_risk

use mctm_coreset::data::equity;
use mctm_coreset::mctm::density::marginal_sigmas;
use mctm_coreset::prelude::*;

fn main() -> Result<(), ApiError> {
    let (n_days, n_stocks, k) = (10_000, 10, 300);
    let mut rng = Rng::new(1985);
    let returns = equity::generate(n_days, n_stocks, &mut rng);
    println!("{n_days} trading days × {n_stocks} stocks (~40y of daily returns)");

    let opts = FitOptions { max_iters: 200, ..Default::default() };

    println!("fitting full data (this is the slow path the paper attacks)...");
    let full = SessionBuilder::new()
        .budget(n_days) // identity coreset ⇒ exact full fit
        .seed(11)
        .fit_options(opts.clone())
        .build()?
        .fit(&returns)?;
    println!(
        "  full: nll={:.1} in {:.1}s",
        full.diagnostics().fit_nll,
        full.diagnostics().fit_seconds
    );

    let model = SessionBuilder::new()
        .method("l2-hull")
        .budget(k)
        .seed(11)
        .fit_options(opts)
        .build()?
        .fit(&returns)?;
    println!(
        "  coreset (k={}): nll={:.1}, λ-error vs full = {:.3}",
        model.diagnostics().coreset.size,
        model.diagnostics().fit_nll,
        lambda_error(model.params(), full.params())
    );

    // implied dependence: σ_j of h̃_j(Y) under the fitted copula — a
    // proxy for how strongly stock j loads on the common structure
    let sig_full = marginal_sigmas(full.params());
    let sig_core = marginal_sigmas(model.params());
    println!("\nimplied marginal sigmas (full vs coreset):");
    for s in 0..n_stocks {
        println!("  stock {s:>2}: {:.3} vs {:.3}", sig_full[s], sig_core[s]);
    }
    let max_rel = sig_full
        .iter()
        .zip(&sig_core)
        .map(|(a, b)| ((a - b) / a).abs())
        .fold(0.0f64, f64::max);
    println!("max relative sigma deviation: {:.1}%", 100.0 * max_rel);

    // tail behaviour straight off the query surface: the 1% left-tail
    // quantile of each fitted margin (a VaR-like number)
    println!("\n1% left-tail (VaR-like) of margin 0:");
    for (label, m) in [("full", &full), ("coreset", &model)] {
        println!("  {label:>7}: {:+.4} (daily return)", m.marginal_quantile(0, 0.01));
    }
    println!("\nequity_risk OK");
    Ok(())
}
