//! Quickstart on the PR-4 facade: builder → session → fitted model.
//! Build an ℓ₂-hull coreset of 10 000 correlated samples, fit the MCTM
//! on ~30 weighted points, compare against the full fit, then serve
//! queries (density, CDF, quantiles, conditional samples) from the
//! fitted model.
//!
//! Run: make example   (or: cargo run --release --example quickstart)

use mctm_coreset::prelude::*;

fn main() -> Result<(), ApiError> {
    // 1. data: 10 000 samples of a correlated bivariate distribution.
    //    Any DataSource works here — an in-memory Mat, a DGP generator,
    //    or a shard stream (which would switch fit() to Merge & Reduce).
    let mut rng = Rng::new(42);
    let data = Dgp::BivariateNormal.generate(10_000, &mut rng);
    println!("generated {} x {} samples", data.rows, data.cols);

    // 2. full-data baseline through the same facade: budget ≥ n is the
    //    identity coreset, i.e. an exact full fit
    let full = SessionBuilder::new()
        .budget(data.rows)
        .seed(7)
        .build()?
        .fit(&data)?;
    println!(
        "full fit     : nll = {:>10.2}  ({} iters, {:.2}s)",
        full.diagnostics().fit_nll,
        full.diagnostics().fit_iters,
        full.diagnostics().fit_seconds
    );

    // 3. the paper's ℓ₂-hull coreset: 30 points instead of 10 000
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(30)
        .seed(7)
        .build()?;
    let model = session.fit(&data)?;
    let diag = model.diagnostics();
    println!(
        "coreset      : {} points ({} sensitivity-sampled + {} hull), total weight {:.0}",
        diag.coreset.size,
        diag.coreset.size - diag.coreset.n_hull,
        diag.coreset.n_hull,
        diag.coreset.total_weight
    );
    println!(
        "coreset fit  : nll = {:>10.2}  ({} iters, {:.3}s)",
        diag.fit_nll, diag.fit_iters, diag.fit_seconds
    );

    // 4. quality: evaluate coreset params on the FULL data
    let lr = loglik_ratio(
        model.nll(&data),
        full.diagnostics().fit_nll,
        data.rows,
        data.cols,
    );
    println!("log-likelihood ratio (→1 is perfect): {lr:.4}");
    println!(
        "theta L2 distance : {:.4}",
        theta_l2(model.params(), full.params())
    );
    println!(
        "lambda error      : {:.4}",
        lambda_error(model.params(), full.params())
    );
    println!(
        "fitted dependence λ₂₁: full = {:+.3}, coreset = {:+.3}",
        full.params().lambda(1, 0),
        model.params().lambda(1, 0)
    );

    // 5. the model is a query server: densities, CDFs, quantiles and
    //    conditional draws — and it is Send + Sync, so many threads can
    //    hit one instance concurrently
    println!(
        "median / 90% quantile of margin 0: {:+.3} / {:+.3}",
        model.marginal_quantile(0, 0.5),
        model.marginal_quantile(0, 0.9)
    );
    println!("log-density at the origin: {:.3}", model.log_density(&[0.0, 0.0]));
    let cond = model.sample_conditional(&[1.5], 500, &mut rng);
    let mean_y2 = (0..cond.rows).map(|r| cond.at(r, 1)).sum::<f64>() / cond.rows as f64;
    println!("E[y₂ | y₁ = 1.5] ≈ {mean_y2:+.3} (ρ = 0.7 ⇒ expect ≈ +1.05)");

    assert!(lr < 2.5, "coreset fit should approximate the full fit");
    println!("\nquickstart OK — 30 points reproduced the 10k-sample fit");
    Ok(())
}
