//! Quickstart: build an ℓ₂-hull coreset of 10 000 correlated samples,
//! fit the MCTM on 30 weighted points, and compare against the full fit.
//!
//! Run: cargo run --release --example quickstart

use mctm_coreset::coordinator::experiment::{design_of, full_fit};
use mctm_coreset::coreset::{build_coreset, Method};
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::fit::{fit_native, FitOptions};
use mctm_coreset::mctm::{self, lambda_error, loglik_ratio, theta_l2, ModelSpec};
use mctm_coreset::util::rng::Rng;
use mctm_coreset::util::Stopwatch;

fn main() {
    // 1. data: 10 000 samples of a correlated bivariate distribution
    let mut rng = Rng::new(42);
    let data = Dgp::BivariateNormal.generate(10_000, &mut rng);
    println!("generated {} x {} samples", data.rows, data.cols);

    // 2. Bernstein design (d = 7 basis functions per margin)
    let design = design_of(&data, 7);
    let spec = ModelSpec::new(2, 7);
    let opts = FitOptions::default();

    // 3. full-data baseline
    let sw = Stopwatch::start();
    let full = full_fit(&design, spec, &opts);
    println!(
        "full fit     : nll = {:>10.2}  ({} iters, {:.2}s)",
        full.fit.nll,
        full.fit.iters,
        sw.secs()
    );

    // 4. the paper's ℓ₂-hull coreset: 30 points instead of 10 000
    let cs = build_coreset(&design, Method::L2Hull, 30, &mut rng);
    println!(
        "coreset      : {} points ({} sensitivity-sampled + {} hull), total weight {:.0}",
        cs.len(),
        cs.len() - cs.n_hull,
        cs.n_hull,
        cs.total_weight()
    );

    // 5. fit on the weighted coreset
    let sw = Stopwatch::start();
    let sub = design.select(&cs.indices);
    let fit = fit_native(spec, &sub, cs.weights.clone(), &opts);
    println!(
        "coreset fit  : nll = {:>10.2}  ({} iters, {:.3}s)",
        fit.nll,
        fit.iters,
        sw.secs()
    );

    // 6. quality: evaluate coreset params on the FULL data
    let nll_on_full = mctm::nll(&design, &[], &fit.params);
    let lr = loglik_ratio(nll_on_full, full.fit.nll, design.n, design.j);
    println!("log-likelihood ratio (→1 is perfect): {lr:.4}");
    println!("theta L2 distance : {:.4}", theta_l2(&fit.params, &full.fit.params));
    println!("lambda error      : {:.4}", lambda_error(&fit.params, &full.fit.params));
    println!(
        "fitted dependence λ₂₁: full = {:+.3}, coreset = {:+.3}",
        full.fit.params.lambda(1, 0),
        fit.params.lambda(1, 0)
    );
    assert!(lr < 2.5, "coreset fit should approximate the full fit");
    println!("\nquickstart OK — 30 points reproduced the 10k-sample fit");
}
