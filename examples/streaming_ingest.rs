//! Streaming / distributed scenario (paper §4): the coreset is built
//! over a shard stream with bounded memory via Merge & Reduce — the
//! producer thread is backpressured by a bounded channel, so the
//! pipeline never buffers more than `queue_cap` shards no matter how
//! large the stream is. Through the facade this is just `Session::fit`
//! on a shard source: the session notices the source streams and takes
//! the Merge & Reduce path automatically.
//!
//! Run: cargo run --release --example streaming_ingest

use mctm_coreset::prelude::*;

fn main() -> Result<(), ApiError> {
    let (total, shard, k) = (200_000usize, 10_000usize, 100usize);
    println!("streaming {total} rows in shards of {shard} (Merge & Reduce, k={k})");

    // producer: an endless-looking DGP source, sharded
    let mut gen_rng = Rng::new(31);
    let source = GenShards::new(
        move |n| Dgp::NormalMixture.generate(n, &mut gen_rng),
        2,
        total,
        shard,
    );
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(k)
        .basis_size(7)
        .queue_cap(2) // aggressive backpressure for the demo
        .build()?;
    let model = session.fit(source)?;
    let diag = model.diagnostics();
    let stats = diag.coreset.stream.as_ref().expect("shard sources stream");
    println!(
        "stream done: {} shards, {} reduce steps, peak queue ≤ {}, {:.1}s",
        stats.n_shards, stats.n_reduces, stats.peak_queue, stats.seconds
    );
    println!(
        "final coreset: {} rows, total weight {:.0} (n = {})",
        diag.coreset.size, diag.coreset.total_weight, stats.n_seen
    );
    println!(
        "fit on streamed coreset: nll={:.2} ({} iters)",
        diag.fit_nll, diag.fit_iters
    );

    // quality check vs an in-memory batch fit on a fresh holdout sample
    let mut rng = Rng::new(77);
    let holdout = Dgp::NormalMixture.generate(20_000, &mut rng);
    let batch = SessionBuilder::new()
        .budget(20_000) // identity coreset ⇒ exact batch fit
        .basis_size(7)
        .build()?
        .fit(&holdout)?;
    // the streamed fit's params live on the streamed coreset's scaled
    // axis — FittedModel::nll evaluates them with that scaler, so no
    // manual design plumbing is needed
    let lr = loglik_ratio(
        model.nll(&holdout),
        batch.diagnostics().fit_nll,
        holdout.rows,
        2,
    );
    println!("holdout log-lik ratio (streamed params vs batch fit): {lr:.4}");
    assert!(lr < 1.5, "streamed coreset lost too much: {lr}");
    println!("streaming_ingest OK");
    Ok(())
}
