//! Streaming / distributed scenario (paper §4): the coreset is built
//! over a shard stream with bounded memory via Merge & Reduce — the
//! producer thread is backpressured by a bounded channel, so the
//! pipeline never buffers more than `queue_cap` shards no matter how
//! large the stream is. The final coreset is fitted like any other.
//!
//! Run: cargo run --release --example streaming_ingest

use mctm_coreset::coordinator::experiment::design_of;
use mctm_coreset::coordinator::pipeline::StreamingPipeline;
use mctm_coreset::coreset::Method;
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::data::GenShards;
use mctm_coreset::fit::{fit_native, FitOptions};
use mctm_coreset::mctm::{self, loglik_ratio, ModelSpec};
use mctm_coreset::util::rng::Rng;

fn main() {
    let (total, shard, k) = (200_000usize, 10_000usize, 100usize);
    println!("streaming {total} rows in shards of {shard} (Merge & Reduce, k={k})");

    // producer: an endless-looking DGP source, sharded
    let mut gen_rng = Rng::new(31);
    let source = GenShards::new(
        move |n| Dgp::NormalMixture.generate(n, &mut gen_rng),
        2,
        total,
        shard,
    );
    let mut pipeline = StreamingPipeline::new(Method::L2Hull, k, 7);
    pipeline.queue_cap = 2; // aggressive backpressure for the demo
    let (coreset, stats) = pipeline.run(source);
    println!(
        "stream done: {} shards, {} reduce steps, peak queue ≤ {}, {:.1}s",
        stats.n_shards, stats.n_reduces, stats.peak_queue, stats.seconds
    );
    println!(
        "final coreset: {} rows, total weight {:.0} (n = {})",
        coreset.len(),
        coreset.weights.iter().sum::<f64>(),
        stats.n_seen
    );

    // fit the streamed coreset
    let spec = ModelSpec::new(2, 7);
    let opts = FitOptions::default();
    let design = design_of(&coreset.rows, 7);
    let fit = fit_native(spec, &design, coreset.weights.clone(), &opts);
    println!("fit on streamed coreset: nll={:.2} ({} iters)", fit.nll, fit.iters);

    // quality check vs an in-memory batch fit on a fresh holdout sample
    let mut rng = Rng::new(77);
    let holdout = Dgp::NormalMixture.generate(20_000, &mut rng);
    let ho_design = design_of(&holdout, 7);
    let batch = fit_native(spec, &ho_design, Vec::new(), &opts);
    // the streamed fit's params live on the streamed coreset's scaled
    // axis — evaluate on a holdout design sharing that scaler
    let ho_stream_design = mctm_coreset::basis::Design::build_with_scaler(
        &holdout,
        7,
        design.scaler.clone(),
    );
    let nll_stream_on_holdout = mctm::nll(&ho_stream_design, &[], &fit.params);
    let lr = loglik_ratio(nll_stream_on_holdout, batch.nll, ho_design.n, 2);
    println!("holdout log-lik ratio (streamed params vs batch fit): {lr:.4}");
    assert!(lr < 1.5, "streamed coreset lost too much: {lr}");
    println!("streaming_ingest OK");
}
