//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Proves all layers compose (recorded in EXPERIMENTS.md §E2E):
//!   L3 (rust)   generates a 100k-row / 10-variable terrain workload,
//!               orchestrates the two-pass leverage pipeline and the
//!               coreset construction;
//!   L2/L1 (AOT) every numeric hot path runs through the PJRT-compiled
//!               HLO artifacts — Pallas gram + leverage kernels for the
//!               sampling scores, the jax nll_grad for L-BFGS fitting,
//!               and the fused Pallas nll_eval for the final metric;
//!   Python is never executed — only the artifacts are.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_xla_pipeline

use mctm_coreset::basis::Design;
use mctm_coreset::coreset::hull::select_hull_points;
use mctm_coreset::data::covertype;
use mctm_coreset::fit::{fit_with, FitOptions};
use mctm_coreset::linalg::{Cholesky, Mat};
use mctm_coreset::mctm::{loglik_ratio, ModelSpec};
use mctm_coreset::runtime::engine::TiledLeverage;
use mctm_coreset::runtime::{Engine, XlaNll};
use mctm_coreset::util::rng::{AliasTable, Rng};
use mctm_coreset::util::Stopwatch;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let scale_n: usize = std::env::var("E2E_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let k = 500usize;
    let (j, d) = (10usize, 7usize);

    println!("=== e2e: MCTM coreset pipeline, all layers ===");
    let engine = Engine::new(Path::new("artifacts"))?;
    println!("PJRT platform: {}", engine.platform());

    // ---- L3: workload generation (data-pipeline source) ---------------
    let sw = Stopwatch::start();
    let mut rng = Rng::new(2026);
    let data = covertype::generate(scale_n, &mut rng);
    println!("[L3] generated {}×{} terrain rows in {:.1}s", data.rows, data.cols, sw.secs());

    let design = Design::build(&data, d, 0.01);
    let scaled = design.scaler.transform(&data);
    let spec = ModelSpec::new(j, d);

    // ---- L1/L2: leverage pipeline through Pallas artifacts ------------
    let sw = Stopwatch::start();
    let lev = TiledLeverage::new(&engine, j * d)?;
    let stacked = design.stacked();
    let gram_flat = lev.gram(&stacked.data)?; // Pallas tiled AᵀA
    let mut gram = Mat::from_vec(j * d, j * d, gram_flat);
    let stab = 1e-10 * gram.trace() / gram.rows as f64;
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += stab;
    }
    let ch = Cholesky::new(&gram)?;
    let linv = ch.l_inverse();
    let scores = lev.scores(&stacked.data, &linv.data)?; // Pallas leverage
    let sum_scores: f64 = scores.iter().sum();
    println!(
        "[L1] leverage pipeline (pallas gram + leverage artifacts): Σu = {:.1} in {:.1}s",
        sum_scores,
        sw.secs()
    );

    // ---- L3: Algorithm 1 — sensitivity sample + hull augmentation -----
    let sw = Stopwatch::start();
    let n = design.n;
    let sens: Vec<f64> = scores.iter().map(|u| u + 1.0 / n as f64).collect();
    let k1 = (0.8 * k as f64) as usize;
    let table = AliasTable::new(&sens);
    let mut indices = Vec::with_capacity(k);
    let mut weights = Vec::with_capacity(k);
    for _ in 0..k1 {
        let i = table.sample(&mut rng);
        indices.push(i);
        weights.push(1.0 / (k1 as f64 * table.p(i)));
    }
    let dp = design.deriv_points();
    let hull = select_hull_points(&dp, k - k1, &mut rng);
    let mut n_hull = 0;
    let seen: std::collections::HashSet<usize> = indices.iter().cloned().collect();
    for p in hull {
        let obs = p / j;
        if !seen.contains(&obs) {
            indices.push(obs);
            weights.push(1.0);
            n_hull += 1;
        }
    }
    println!(
        "[L3] coreset: {} rows ({} sampled + {} hull) from n={} in {:.1}s — {:.0}× reduction",
        indices.len(),
        k1,
        n_hull,
        n,
        sw.secs(),
        n as f64 / indices.len() as f64
    );

    // ---- L2: fit via the AOT nll_grad artifact -------------------------
    let sw = Stopwatch::start();
    let sub_scaled = scaled.select_rows(&indices);
    let obj = XlaNll::from_scaled(&engine, j, d, &sub_scaled, weights)?;
    let opts = FitOptions { max_iters: 200, ..Default::default() };
    let fit = fit_with(&obj, spec, &opts);
    let coreset_fit_secs = sw.secs();
    println!(
        "[L2] coreset fit through nll_grad artifact: nll={:.2}, {} iters, {:.1}s",
        fit.nll, fit.iters, coreset_fit_secs
    );

    // ---- L1: evaluate on the FULL data via the fused Pallas kernel ----
    let sw = Stopwatch::start();
    let full_obj = XlaNll::from_scaled(&engine, j, d, &scaled, Vec::new())?;
    let nll_coreset_on_full = full_obj.eval(&fit.params.x)?;
    println!(
        "[L1] fused nll_eval over all {n} rows: {:.2} in {:.1}s",
        nll_coreset_on_full,
        sw.secs()
    );

    // ---- headline: compare against a full-data XLA fit ----------------
    let sw = Stopwatch::start();
    let full_fit = fit_with(&full_obj, spec, &opts);
    let full_secs = sw.secs();
    let lr = loglik_ratio(nll_coreset_on_full, full_fit.nll, n, j);
    println!("[L2] FULL-data fit through the same artifact: nll={:.2}, {:.1}s", full_fit.nll, full_secs);
    println!("\n=== headline (paper §3.2 shape) ===");
    println!("data reduction   : {n} → {} rows", indices.len());
    println!("log-lik ratio    : {lr:.4}  (→1 = lossless)");
    println!(
        "fit speedup      : {:.1}× ({:.1}s → {:.1}s)",
        full_secs / coreset_fit_secs,
        full_secs,
        coreset_fit_secs
    );
    anyhow::ensure!(lr.is_finite() && lr < 2.0, "coreset LR degraded: {lr}");
    println!("e2e OK");
    Ok(())
}
