//! Conditional MCTM (distributional regression) scenario — the paper's
//! §4 extension with a linear conditional structure: model the joint
//! distribution of two responses given a feature, fit it from a
//! leverage-score coreset over the EXTENDED stacked matrix (dJ + q
//! columns), and verify the conditional effect survives the reduction.
//!
//! Run: cargo run --release --example conditional_regression

use mctm_coreset::coreset::leverage::leverage_scores;
use mctm_coreset::fit::{minimize, FitOptions};
use mctm_coreset::linalg::Mat;
use mctm_coreset::mctm::conditional::{cond_init, cond_nll_grad, CondDesign, CondNll, CondSpec};
use mctm_coreset::util::rng::{AliasTable, Rng};
use mctm_coreset::util::Stopwatch;

fn main() {
    // synthetic "weather" panel: responses (temperature, humidity),
    // feature elevation; temperature drops with elevation, humidity
    // correlates negatively with temperature
    let n = 50_000;
    let mut rng = Rng::new(2024);
    let mut y = Mat::zeros(n, 2);
    let mut x = Mat::zeros(n, 1);
    for i in 0..n {
        let elev = rng.uniform(0.0, 3.0); // km
        let temp = 25.0 - 6.5 * elev + rng.normal_ms(0.0, 2.0);
        let humid = 60.0 - 1.2 * (temp - 15.0) + rng.normal_ms(0.0, 8.0);
        *x.at_mut(i, 0) = elev;
        *y.at_mut(i, 0) = temp;
        *y.at_mut(i, 1) = humid;
    }
    println!("{n} obs: responses (temp, humidity), feature elevation");

    let spec = CondSpec::new(2, 7, 1);
    let cd = CondDesign::build(&y, &x, 7, 0.01);
    let opts = FitOptions { max_iters: 250, ..Default::default() };

    // full conditional fit
    let sw = Stopwatch::start();
    let obj = CondNll { spec, cd: &cd, weights: Vec::new() };
    let (full, full_nll, _, _) = minimize(&obj, cond_init(spec), &opts);
    let full_secs = sw.secs();
    println!("full conditional fit: nll={full_nll:.1} in {full_secs:.1}s");

    // coreset on the extended stacked matrix
    let sw = Stopwatch::start();
    let stacked = cd.stacked();
    println!("extended stacked matrix: {} × {} (dJ + q)", stacked.rows, stacked.cols);
    let u = leverage_scores(&stacked).expect("leverage");
    let s: Vec<f64> = u.iter().map(|ui| ui + 1.0 / n as f64).collect();
    let table = AliasTable::new(&s);
    let k = 400;
    let mut idx = Vec::with_capacity(k);
    let mut w = Vec::with_capacity(k);
    for _ in 0..k {
        let i = table.sample(&mut rng);
        idx.push(i);
        w.push(1.0 / (k as f64 * table.p(i)));
    }
    let sub = cd.select(&idx);
    let obj_sub = CondNll { spec, cd: &sub, weights: w };
    let (coreset, _, _, _) = minimize(&obj_sub, cond_init(spec), &opts);
    let coreset_secs = sw.secs();

    // conditional effects γ (on the latent scale): sign + stability
    let g_off = spec.n_params() - spec.j * (spec.j - 1) / 2 - spec.j * 1;
    println!("\nconditional effects γ (latent scale):");
    println!("  γ_temp  : full {:+.3}  coreset {:+.3}", full[g_off], coreset[g_off]);
    println!("  γ_humid : full {:+.3}  coreset {:+.3}", full[g_off + 1], coreset[g_off + 1]);
    println!("  λ₂₁     : full {:+.3}  coreset {:+.3}",
        full[spec.n_params() - 1], coreset[spec.n_params() - 1]);

    // likelihood of the coreset params on the full data
    let (nll_on_full, _) = cond_nll_grad(&cd, &[], spec, &coreset);
    println!("\nnll(full | coreset params) = {nll_on_full:.1} (full fit {full_nll:.1})");
    println!("speedup: {:.1}× ({full_secs:.1}s → {coreset_secs:.1}s), reduction {}×",
        full_secs / coreset_secs.max(1e-9), n / k);

    let rel = (full[g_off] - coreset[g_off]).abs() / full[g_off].abs();
    assert!(rel < 0.4, "conditional effect drifted {rel:.2}");
    // temperature falls with elevation ⇒ γ on the latent (increasing) scale
    // must be positive after the whitening sign convention… just require
    // consistent signs between full and coreset
    assert_eq!(full[g_off].signum(), coreset[g_off].signum());
    println!("conditional_regression OK");
}
