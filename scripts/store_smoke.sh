#!/usr/bin/env bash
# End-to-end smoke of the out-of-core ingestion loop (PR 9):
#   1. generate a deterministic CSV
#   2. `mctm-coreset import` — one-pass conversion to a column store
#   3. fit + save from the CSV and from the store with identical knobs;
#      the artifacts must be BYTE-identical (artifacts serialize f64
#      bits exactly, so `cmp` proves the store-backed fit is bitwise
#      equal to the in-memory one)
#   4. `mctm-coreset stream --set dataset=store:…` — the streaming
#      registry path reads the store and sees every row
# Wired into `make ci` via the store-smoke target.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${MCTM_BIN:-$ROOT/target/release/mctm-coreset}"
TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT

if [ ! -x "$BIN" ]; then
    echo "== building release binary =="
    cargo build --release --manifest-path "$ROOT/rust/Cargo.toml"
fi

echo "== generate a deterministic 240-row CSV =="
awk 'BEGIN {
    for (i = 0; i < 240; i++)
        printf "%.17g,%.17g\n", sin(i * 0.7) + 0.05 * i, cos(i * 1.3) - 0.02 * i
}' >"$TMP/rows.csv"
[ "$(wc -l <"$TMP/rows.csv")" -eq 240 ]

echo "== import: CSV -> column store in one bounded-memory pass =="
"$BIN" import --set "dataset=file:$TMP/rows.csv" \
    --out "$TMP/rows.store" --chunk-rows 64

CFG=(--set n=240 --set k=25 --set d=5 --set max_iters=80 --set seed=5)

echo "== fit + save from the CSV (in-memory reference) =="
"$BIN" save --out "$TMP/from_csv.mctm" --sketch "$TMP/from_csv_sketch.mctm" \
    --set "dataset=file:$TMP/rows.csv" "${CFG[@]}"

echo "== fit + save from the store (out-of-core path) =="
"$BIN" save --out "$TMP/from_store.mctm" --sketch "$TMP/from_store_sketch.mctm" \
    --set "dataset=store:$TMP/rows.store" "${CFG[@]}"

echo "== store-backed artifacts are byte-identical to the CSV ones =="
cmp "$TMP/from_csv.mctm" "$TMP/from_store.mctm"
cmp "$TMP/from_csv_sketch.mctm" "$TMP/from_store_sketch.mctm"

echo "== streaming registry path covers every stored row =="
"$BIN" stream --set "dataset=store:$TMP/rows.store" "${CFG[@]}" \
    | grep -q "stream: n=240"

echo "store smoke OK"
