#!/usr/bin/env bash
# End-to-end smoke of the distributed sketching loop (ISSUE 10):
#   1. two `mctm-coreset work` workers on ephemeral ports
#   2. `mctm-coreset stream`   — the single-process reference run
#   3. `mctm-coreset dist-fit` — same config across both workers;
#      the saved sketch AND model artifacts must be byte-identical
#      to the stream run's (`cmp`)
#   4. kill one worker mid-run  — the coordinator retries, declares the
#      worker dead, reassigns its range, and still produces the exact
#      same bytes
# Wired into `make ci` via the dist-smoke target.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${MCTM_BIN:-$ROOT/target/release/mctm-coreset}"
TMP="$(mktemp -d)"
W1_PID=""
W2_PID=""
trap '[ -n "$W1_PID" ] && kill "$W1_PID" 2>/dev/null; [ -n "$W2_PID" ] && kill "$W2_PID" 2>/dev/null; rm -rf "$TMP"' EXIT

if [ ! -x "$BIN" ]; then
    echo "== building release binary =="
    cargo build --release --manifest-path "$ROOT/rust/Cargo.toml"
fi

# the geometry and knobs shared by every run below — byte-identity only
# holds (and is only claimed) for identical configs
CFG=(--shards 8 --shard-size 500 --set k=200 --set d=5 --set max_iters=60)

start_worker() { # $1 = log file; prints nothing, sets REPLY to the pid
    "$BIN" work --listen 127.0.0.1:0 >"$1" 2>&1 &
    REPLY=$!
}

worker_addr() { # $1 = log file, $2 = pid; prints the announced address
    local addr=""
    for _ in $(seq 1 100); do
        addr="$(sed -n 's|^worker listening on \([0-9.:]*\)$|\1|p' "$1")"
        [ -n "$addr" ] && break
        kill -0 "$2" 2>/dev/null || { cat "$1"; return 1; }
        sleep 0.1
    done
    [ -n "$addr" ] || { echo "worker never announced its address" >&2; cat "$1" >&2; return 1; }
    echo "$addr"
}

echo "== start two workers on ephemeral ports =="
start_worker "$TMP/w1.log"; W1_PID=$REPLY
start_worker "$TMP/w2.log"; W2_PID=$REPLY
A1="$(worker_addr "$TMP/w1.log" "$W1_PID")"
A2="$(worker_addr "$TMP/w2.log" "$W2_PID")"
echo "   $A1  $A2"

echo "== stream: the single-process reference =="
"$BIN" stream --out "$TMP/stream.model.mctm" --sketch "$TMP/stream.sketch.mctm" "${CFG[@]}"

echo "== dist-fit: same config across both workers =="
"$BIN" dist-fit --workers "$A1,$A2" \
    --out "$TMP/dist.model.mctm" --sketch "$TMP/dist.sketch.mctm" "${CFG[@]}"

echo "== distributed bytes == single-process bytes =="
cmp "$TMP/stream.sketch.mctm" "$TMP/dist.sketch.mctm"
cmp "$TMP/stream.model.mctm" "$TMP/dist.model.mctm"

echo "== kill a worker mid-run: range reassigns, bytes unchanged =="
"$BIN" dist-fit --workers "$A1,$A2" \
    --out "$TMP/recover.model.mctm" --sketch "$TMP/recover.sketch.mctm" "${CFG[@]}" \
    >"$TMP/recover.log" 2>&1 &
RUN_PID=$!
sleep 0.2
kill -9 "$W1_PID" 2>/dev/null || true
W1_PID=""
if ! wait "$RUN_PID"; then
    echo "dist-fit did not survive the worker kill"; cat "$TMP/recover.log"; exit 1
fi
cat "$TMP/recover.log"
cmp "$TMP/stream.sketch.mctm" "$TMP/recover.sketch.mctm"
cmp "$TMP/stream.model.mctm" "$TMP/recover.model.mctm"

echo "dist smoke OK"
