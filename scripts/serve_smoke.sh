#!/usr/bin/env bash
# End-to-end smoke of the persist & serve loop (ISSUE 7):
#   1. `mctm-coreset save`  — fit once, write model + sketch artifacts
#   2. `mctm-coreset load`  — both artifacts parse and summarize
#   3. same-seed re-save    — artifact bytes are byte-identical
#   4. `mctm-serve`         — serve the model directory over HTTP and
#      hit every query endpoint (density, cdf, quantile, sample,
#      conditional), the listing/health/metrics endpoints, one pinned
#      edge case (cdf at +inf), and one typed 400.
# Wired into `make ci` via the serve-smoke target.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BIN="${MCTM_BIN:-$ROOT/target/release/mctm-coreset}"
SERVE_BIN="${MCTM_SERVE_BIN:-$ROOT/target/release/mctm-serve}"
TMP="$(mktemp -d)"
SERVER_PID=""
trap '[ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null; rm -rf "$TMP"' EXIT

if [ ! -x "$BIN" ] || [ ! -x "$SERVE_BIN" ]; then
    echo "== building release binaries =="
    cargo build --release --manifest-path "$ROOT/rust/Cargo.toml"
fi

CFG=(--set n=2000 --set k=200 --set d=5 --set max_iters=60)
mkdir -p "$TMP/models"

echo "== save: fit once, persist model + sketch =="
"$BIN" save --out "$TMP/models/demo.mctm" --sketch "$TMP/demo_sketch.mctm" "${CFG[@]}"

echo "== load: both artifact kinds parse =="
"$BIN" load "$TMP/models/demo.mctm" | grep -q "model artifact"
"$BIN" load "$TMP/demo_sketch.mctm" | grep -q "sketch artifact"

echo "== determinism: same seed, same bytes =="
"$BIN" save --out "$TMP/demo2.mctm" "${CFG[@]}"
cmp "$TMP/models/demo.mctm" "$TMP/demo2.mctm"

echo "== serve: bring up the HTTP layer on an ephemeral port =="
"$SERVE_BIN" --models "$TMP/models" --addr 127.0.0.1:0 >"$TMP/serve.log" 2>&1 &
SERVER_PID=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR="$(sed -n 's|^serving on \(http://[0-9.:]*\)$|\1|p' "$TMP/serve.log")"
    [ -n "$ADDR" ] && break
    kill -0 "$SERVER_PID" 2>/dev/null || { cat "$TMP/serve.log"; exit 1; }
    sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its address"; cat "$TMP/serve.log"; exit 1; }
echo "   $ADDR"

echo "== query every endpoint =="
python3 - "$ADDR" <<'PYEOF'
import json
import sys
import urllib.error
import urllib.request

addr = sys.argv[1]

def get(path):
    with urllib.request.urlopen(addr + path, timeout=10) as r:
        return json.loads(r.read().decode())

models = get("/v1/models")["models"]
assert [m["name"] for m in models] == ["demo"], models

d = get("/v1/models/demo/density?y=0.5,-0.25")
assert isinstance(d["log_density"], float), d
c = get("/v1/models/demo/cdf?j=0&y=1.0")
assert 0.0 <= c["cdf"] <= 1.0, c
q = get("/v1/models/demo/quantile?j=0&p=0.5")
assert isinstance(q["quantile"], float), q
s = get("/v1/models/demo/sample?n=5&seed=3")
assert len(s["rows"]) == 5 and len(s["rows"][0]) == 2, s
assert s == get("/v1/models/demo/sample?n=5&seed=3"), "seeded sampling not deterministic"
k = get("/v1/models/demo/conditional?given=0.8&n=4&seed=7")
assert len(k["rows"]) == 4 and k["rows"][0][0] == 0.8, k

# pinned edge semantics over the wire
assert get("/v1/models/demo/cdf?j=0&y=inf")["cdf"] == 1.0
assert get("/v1/models/demo/cdf?j=0&y=-inf")["cdf"] == 0.0

# invalid queries are typed 400s, not worker deaths
try:
    get("/v1/models/demo/quantile?j=0&p=1.5")
    raise SystemExit("p=1.5 should be HTTP 400")
except urllib.error.HTTPError as e:
    assert e.code == 400, e.code

m = get("/metrics")
assert m["density"] >= 1 and m["cdf"] >= 4 and m["quantile"] >= 2, m
assert m["sample"] >= 2 and m["conditional"] >= 1 and m["errors"] >= 1, m
h = get("/health")
assert h["status"] == "ok" and h["models"] == 1, h
print("   metrics:", json.dumps(m))
PYEOF

echo "serve smoke OK"
