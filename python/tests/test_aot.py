"""AOT build round-trip: lower a tiny config into a temp dir, check the
artifact files + manifest, and re-execute the lowered HLO text through
the XLA client to confirm it still computes the reference NLL."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_build_tiny_config(tmp_path):
    manifest = aot.build(str(tmp_path), [(2, 4)], tile=16)
    names = {e["name"] for e in manifest["entries"]}
    assert f"nll_grad_j2_d4_t16" in names
    assert f"nll_eval_j2_d4_t16" in names
    assert f"gram_d8_t16" in names
    assert f"leverage_d8_t16" in names
    for e in manifest["entries"]:
        path = tmp_path / (e["name"] + ".hlo.txt")
        assert path.exists()
        text = path.read_text()
        assert text.startswith("HloModule")
    # manifest file itself
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["tile"] == 16
    assert len(on_disk["entries"]) == 4


def test_lowered_nll_grad_executes_and_matches_ref(tmp_path):
    """Compile the HLO text with the in-process XLA client and compare
    against the jnp oracle — the same round trip the Rust runtime does."""
    from jax._src.lib import xla_client as xc

    j, d, tile = 2, 4, 8
    p = model.n_params(j, d)
    fn = lambda params, y, w: model.nll_grad(params, y, w, j, d)
    lowered = jax.jit(fn).lower(aot.spec(p), aot.spec(tile, j), aot.spec(tile))
    text = aot.to_hlo_text(lowered)

    backend = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text)
    # execute through jax instead (the rust round trip is covered by the
    # rust integration tests); here we just confirm the lowering is
    # numerically identical to the oracle
    rng = np.random.default_rng(0)
    params = jnp.asarray(rng.normal(0, 0.5, p))
    y = jnp.asarray(rng.uniform(0.05, 0.95, (tile, j)))
    w = jnp.ones(tile)
    v, g = jax.jit(fn)(params, y, w)
    rv, rg = ref.mctm_nll_grad_ref(params, y, w, j, d)
    np.testing.assert_allclose(v, rv, rtol=1e-10)
    np.testing.assert_allclose(g, rg, rtol=1e-8, atol=1e-10)
    assert comp is not None
    assert backend is not None


def test_make_artifacts_is_incremental():
    """`make artifacts` must be a no-op when the manifest is newer than
    every python source (documented contract)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..")
    manifest = os.path.join(root, "artifacts", "manifest.json")
    if not os.path.exists(manifest):
        import pytest

        pytest.skip("artifacts not built")
    m_time = os.path.getmtime(manifest)
    src_dir = os.path.join(root, "python", "compile")
    newest_src = max(
        os.path.getmtime(os.path.join(dirpath, f))
        for dirpath, _, files in os.walk(src_dir)
        for f in files
        if f.endswith(".py")
    )
    # if sources are newer the build would (correctly) re-run; both
    # states are consistent — just assert the make rule's inputs exist
    assert m_time > 0 and newest_src > 0
