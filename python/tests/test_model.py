"""L2 model tests: gradient correctness, shapes, parametrization
invariants, and (slow, opt-in) AOT lowering round-trips."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def rand_params(j, d, scale=0.5):
    return jnp.asarray(RNG.normal(0, scale, size=model.n_params(j, d)))


def rand_tile(t, j):
    return jnp.asarray(RNG.uniform(0.01, 0.99, size=(t, j)))


# ---------------------------------------------------------------------------
# nll_grad
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    j=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=3, max_value=7),
)
def test_nll_grad_matches_ref(j, d):
    t = 16
    params = rand_params(j, d)
    y = rand_tile(t, j)
    w = jnp.ones(t)
    v, g = model.nll_grad(params, y, w, j, d)
    rv, rg = ref.mctm_nll_grad_ref(params, y, w, j, d)
    np.testing.assert_allclose(v, rv, rtol=1e-10)
    np.testing.assert_allclose(g, rg, rtol=1e-8, atol=1e-10)


def test_nll_grad_finite_difference():
    j, d = 2, 5
    params = rand_params(j, d)
    y = rand_tile(12, j)
    w = jnp.ones(12)
    _, g = model.nll_grad(params, y, w, j, d)
    h = 1e-6
    for k in range(model.n_params(j, d)):
        pp = params.at[k].add(h)
        pm = params.at[k].add(-h)
        fp, _ = model.nll_grad(pp, y, w, j, d)
        fm, _ = model.nll_grad(pm, y, w, j, d)
        fd = (fp - fm) / (2 * h)
        assert abs(float(g[k]) - float(fd)) < 1e-4 * (1 + abs(float(fd)))


def test_nll_eval_matches_nll_grad_value():
    j, d = 3, 6
    params = rand_params(j, d)
    y = rand_tile(24, j)
    w = jnp.asarray(RNG.uniform(0.5, 1.5, size=24))
    v, _ = model.nll_grad(params, y, w, j, d)
    ve = model.nll_eval(params, y, w, j, d)[0]
    np.testing.assert_allclose(ve, v, rtol=1e-10)


def test_weighting_equals_replication():
    j, d = 2, 5
    params = rand_params(j, d)
    y = rand_tile(8, j)
    w = jnp.ones(8).at[3].set(2.0)
    v, _ = model.nll_grad(params, y, w, j, d)
    y2 = jnp.concatenate([y, y[3:4]], axis=0)
    v2, _ = model.nll_grad(params, y2, jnp.ones(9), j, d)
    np.testing.assert_allclose(v, v2, rtol=1e-12)


# ---------------------------------------------------------------------------
# parametrization invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    j=st.integers(min_value=1, max_value=4),
    d=st.integers(min_value=2, max_value=9),
)
def test_theta_monotone(j, d):
    beta = jnp.asarray(RNG.normal(0, 2.0, size=(j, d)))
    theta = ref.theta_from_beta(beta)
    diffs = jnp.diff(theta, axis=-1)
    assert bool(jnp.all(diffs > 0))


def test_unpack_roundtrip_lambda_layout():
    j, d = 4, 3
    p = model.n_params(j, d)
    params = jnp.arange(p, dtype=jnp.float64)
    _, lam = ref.unpack_params(params, j, d)
    # λ block starts at J·d = 12; rows (1,0),(2,0),(2,1),(3,0),(3,1),(3,2)
    assert float(lam[1, 0]) == 12.0
    assert float(lam[2, 0]) == 13.0
    assert float(lam[2, 1]) == 14.0
    assert float(lam[3, 2]) == 17.0
    assert float(lam[0, 0]) == 0.0  # diagonal not stored


# ---------------------------------------------------------------------------
# AOT lowering (structure only — fast; full build exercised by `make
# artifacts` + the Rust integration tests)
# ---------------------------------------------------------------------------

def test_lowering_produces_hlo_text():
    from compile import aot

    p = model.n_params(2, 5)
    fn = lambda params, y, w: model.nll_grad(params, y, w, 2, 5)
    lowered = jax.jit(fn).lower(
        aot.spec(p), aot.spec(32, 2), aot.spec(32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text


def test_manifest_configs_parse():
    from compile import aot

    assert aot.parse_configs("2x7,10x7") == [(2, 7), (10, 7)]
