"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle,
with hypothesis sweeping shapes and dtypes (as far as each kernel's
tiling constraints allow)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import bernstein as bk
from compile.kernels import gram as gk
from compile.kernels import leverage as lk
from compile.kernels import nll as nk
from compile.kernels import ref

SEED = np.random.default_rng(0)


def rand(shape, dtype=np.float64, lo=0.0, hi=1.0):
    return jnp.asarray(
        SEED.uniform(lo, hi, size=shape).astype(dtype)
    )


# ---------------------------------------------------------------------------
# Bernstein design kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=64),
    j=st.integers(min_value=1, max_value=5),
    d=st.integers(min_value=2, max_value=9),
)
def test_bernstein_kernel_matches_ref(t, j, d):
    y = rand((t, j))
    a, ad = bk.bernstein_design(y, d)
    np.testing.assert_allclose(a, ref.bernstein_ref(y, d), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(
        ad, ref.bernstein_deriv_ref(y, d), rtol=1e-10, atol=1e-10
    )


@settings(max_examples=10, deadline=None)
@given(d=st.integers(min_value=2, max_value=12))
def test_bernstein_partition_of_unity(d):
    y = rand((16, 3))
    a, ad = bk.bernstein_design(y, d)
    np.testing.assert_allclose(jnp.sum(a, axis=-1), jnp.ones((16, 3)), rtol=1e-12)
    np.testing.assert_allclose(jnp.sum(ad, axis=-1), jnp.zeros((16, 3)), atol=1e-10)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_bernstein_dtypes(dtype):
    y = rand((8, 2), dtype=dtype)
    a, ad = bk.bernstein_design(y, 7)
    assert a.dtype == y.dtype and ad.dtype == y.dtype
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(a, ref.bernstein_ref(y, 7), rtol=tol, atol=tol)
    np.testing.assert_allclose(ad, ref.bernstein_deriv_ref(y, 7), rtol=tol, atol=tol)


def test_bernstein_derivative_finite_difference():
    y = rand((32, 2), lo=0.05, hi=0.95)
    d = 7
    h = 1e-6
    _, ad = bk.bernstein_design(y, d)
    ap, _ = bk.bernstein_design(y + h, d)
    am, _ = bk.bernstein_design(y - h, d)
    np.testing.assert_allclose(ad, (ap - am) / (2 * h), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Gram kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    d=st.integers(min_value=1, max_value=24),
    tile=st.sampled_from([8, 32, 64]),
)
def test_gram_matches_ref(tiles, d, tile):
    x = rand((tiles * tile, d), lo=-1.0, hi=1.0)
    g = gk.gram(x, row_tile=tile)
    np.testing.assert_allclose(g, ref.gram_ref(x), rtol=1e-10, atol=1e-10)


def test_gram_zero_padding_invariant():
    # the Rust runtime pads the last tile with zero rows
    x = rand((96, 5), lo=-2.0, hi=2.0)
    xp = jnp.concatenate([x, jnp.zeros((32, 5))], axis=0)
    np.testing.assert_allclose(
        gk.gram(xp, row_tile=32), ref.gram_ref(x), rtol=1e-12, atol=1e-12
    )


def test_gram_rejects_partial_tiles():
    with pytest.raises(AssertionError):
        gk.gram(rand((33, 4)), row_tile=32)


# ---------------------------------------------------------------------------
# Leverage kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=2, max_value=16),
)
def test_leverage_matches_ref(tiles, d):
    tile = 32
    x = rand((tiles * tile, d), lo=-1.0, hi=1.0)
    g = np.asarray(ref.gram_ref(x)) + 1e-9 * np.eye(d)
    l = np.linalg.cholesky(g)
    linv = jnp.asarray(np.linalg.inv(l))
    u = lk.leverage(x, linv, row_tile=tile)
    np.testing.assert_allclose(u, ref.leverage_ref(x, linv), rtol=1e-10, atol=1e-12)


def test_leverage_sums_to_rank():
    x = rand((128, 6), lo=-1.0, hi=1.0)
    g = np.asarray(ref.gram_ref(x))
    linv = jnp.asarray(np.linalg.inv(np.linalg.cholesky(g)))
    u = lk.leverage(x, linv, row_tile=64)
    assert abs(float(jnp.sum(u)) - 6.0) < 1e-8


# ---------------------------------------------------------------------------
# Fused NLL kernel
# ---------------------------------------------------------------------------

def random_params(j, d):
    p = j * d + j * (j - 1) // 2
    return jnp.asarray(SEED.normal(0, 0.5, size=p))


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=48),
    j=st.integers(min_value=1, max_value=4),
    d=st.integers(min_value=3, max_value=8),
)
def test_nll_kernel_matches_ref(t, j, d):
    params = random_params(j, d)
    y = rand((t, j), lo=0.01, hi=0.99)
    w = rand((t,), lo=0.1, hi=2.0)
    beta, lam = ref.unpack_params(params, j, d)
    theta = ref.theta_from_beta(beta)
    lam_unit = lam + jnp.eye(j, dtype=params.dtype)
    got = nk.nll_tile(y, w, theta, lam_unit)[0]
    want = ref.mctm_nll_ref(params, y, w, j, d)
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_nll_kernel_zero_weight_padding():
    j, d = 2, 7
    params = random_params(j, d)
    y = rand((32, j), lo=0.01, hi=0.99)
    w = jnp.ones(32).at[20:].set(0.0)
    beta, lam = ref.unpack_params(params, j, d)
    theta = ref.theta_from_beta(beta)
    lam_unit = lam + jnp.eye(j, dtype=params.dtype)
    got = nk.nll_tile(y, w, theta, lam_unit)[0]
    want = ref.mctm_nll_ref(params, y[:20], jnp.ones(20), j, d)
    np.testing.assert_allclose(got, want, rtol=1e-10)
