"""AOT lowering: jax → HLO **text** artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out-dir ../artifacts]
                              [--configs 2x7,3x7,10x7] [--tile 512]

Produces, per (J, d) config:
    nll_grad_j{J}_d{d}_t{T}.hlo.txt   (params, y, w) → (nll, grad)
    nll_eval_j{J}_d{d}_t{T}.hlo.txt   (params, y, w) → (nll[1],)
and per stacked dimension D = J·d:
    gram_d{D}_t{T}.hlo.txt            (x,)          → (gram,)
    leverage_d{D}_t{T}.hlo.txt        (x, linv)     → (scores,)
plus manifest.json describing shapes (consumed by rust/src/runtime).
`make artifacts` skips the build when inputs are unchanged.
"""

import argparse
import json
import os
import sys
from functools import partial

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (gen_hlo.py recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_entry(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build(out_dir: str, configs, tile: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"dtype": "f64", "tile": tile, "entries": []}

    gram_dims = set()
    for (j, d) in configs:
        p = model.n_params(j, d)

        # --- training objective: value + grad --------------------------
        name = f"nll_grad_j{j}_d{d}_t{tile}"
        fn = partial(model.nll_grad, j=j, d=d)
        text = lower_entry(fn, (spec(p), spec(tile, j), spec(tile)))
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "kind": "nll_grad",
                "j": j,
                "d": d,
                "tile": tile,
                "n_params": p,
                "inputs": [[p], [tile, j], [tile]],
                "outputs": [[], [p]],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

        # --- fused forward NLL (pallas kernel) -------------------------
        name = f"nll_eval_j{j}_d{d}_t{tile}"
        fn = partial(model.nll_eval, j=j, d=d)
        text = lower_entry(fn, (spec(p), spec(tile, j), spec(tile)))
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "kind": "nll_eval",
                "j": j,
                "d": d,
                "tile": tile,
                "n_params": p,
                "inputs": [[p], [tile, j], [tile]],
                "outputs": [[1]],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
        gram_dims.add(j * d)

    for dim in sorted(gram_dims):
        # --- leverage pipeline ------------------------------------------
        name = f"gram_d{dim}_t{tile}"
        fn = partial(model.gram, row_tile=tile)
        text = lower_entry(fn, (spec(tile, dim),))
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "kind": "gram",
                "dim": dim,
                "tile": tile,
                "inputs": [[tile, dim]],
                "outputs": [[dim, dim]],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

        name = f"leverage_d{dim}_t{tile}"
        fn = partial(model.leverage, row_tile=tile)
        text = lower_entry(fn, (spec(tile, dim), spec(dim, dim)))
        path = os.path.join(out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "kind": "leverage",
                "dim": dim,
                "tile": tile,
                "inputs": [[tile, dim], [dim, dim]],
                "outputs": [[tile]],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def parse_configs(s: str):
    out = []
    for part in s.split(","):
        j, d = part.lower().split("x")
        out.append((int(j), int(d)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat alias for --out-dir parent target")
    ap.add_argument("--configs", default="2x7,3x7,10x7")
    ap.add_argument("--tile", type=int, default=512)
    args = ap.parse_args(argv)
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    build(out_dir, parse_configs(args.configs), args.tile)
    return 0


if __name__ == "__main__":
    sys.exit(main())
