"""L1 Pallas kernel: rowwise leverage scores u_i = ‖L⁻¹ x_i‖².

Given the inverse Cholesky factor L⁻¹ of the (ridged) Gram matrix —
computed once on the coordinator side — each grid step transforms a
(T, D) row-block by L⁻ᵀ on the MXU and reduces the squared norms on
the VPU. L⁻¹ (D×D ≤ 140×140 f64 ≈ 153 KiB) is resident in VMEM for
every step. interpret=True for CPU execution.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leverage_kernel(x_ref, linv_ref, out_ref):
    x = x_ref[...]          # (T, D)
    linv = linv_ref[...]    # (D, D)
    z = x @ linv.T
    out_ref[...] = jnp.sum(z * z, axis=-1)


def leverage(x, linv, row_tile: int = 512):
    """Leverage scores for all rows of x (n multiple of row_tile)."""
    n, d = x.shape
    assert linv.shape == (d, d)
    assert n % row_tile == 0, f"n={n} not a multiple of tile={row_tile}"
    grid = (n // row_tile,)
    return pl.pallas_call(
        _leverage_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, linv)
