"""L1 Pallas kernel: tiled Gram matrix XᵀX.

The leverage-score pipeline's MXU hot spot: each grid step loads one
(T, D) row-block into VMEM and accumulates the (D, D) output block
(revisited across the whole grid — the classic reduction BlockSpec).
For D ≤ 140 (J=20, d=7) the accumulator is ≤ 153 KiB f64, far inside
VMEM; the (T, D)ᵀ(T, D) product maps onto the MXU systolic array.
interpret=True for CPU execution (see DESIGN.md §6).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    out_ref[...] += x.T @ x


def gram(x, row_tile: int = 512):
    """XᵀX via a row-tiled Pallas reduction. n must be a multiple of
    row_tile (the AOT entry points use fixed tiles; the Rust runtime
    pads the last tile with zero rows, which add nothing to the Gram)."""
    n, d = x.shape
    assert n % row_tile == 0, f"n={n} not a multiple of tile={row_tile}"
    grid = (n // row_tile,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), x.dtype),
        interpret=True,
    )(x)
