"""L1: Pallas kernels for the MCTM coreset pipeline + pure-jnp oracle.

Kernels (all interpret=True — CPU PJRT cannot run Mosaic custom-calls):
  * bernstein — design-matrix evaluation (a, a')
  * gram      — tiled XᵀX reduction (leverage-score pipeline)
  * leverage  — rowwise ‖L⁻¹x‖² scores
  * nll       — fused weighted MCTM NLL tile reduction
Oracle: ref — the correctness baseline every kernel is tested against.
"""

from . import bernstein, gram, leverage, nll, ref  # noqa: F401
