"""L1 Pallas kernel: fused weighted MCTM NLL over one data tile.

The paper's compute hot-spot (Eq. (1)) as a single fused pass: basis
evaluation, marginal transforms, copula combination, log-derivative and
the weighted reduction — all intermediates ((T,J,d) basis tensors,
(T,J) transforms) stay in VMEM; only the scalar partial sum leaves the
kernel. The Rust tiled runner accumulates partials across tiles.

This is the forward/evaluation path (log-likelihood ratios, metric
computation). The *training* entry point (`model.nll_grad`) uses the
same Bernstein kernel for the design tensors but keeps the θ-dependent
tail in jnp so jax.value_and_grad applies — see model.py.
interpret=True for CPU execution (DESIGN.md §6).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bernstein import _basis_columns

ETA_FLOOR = 1e-12


def _nll_kernel(j: int, d: int, y_ref, w_ref, theta_ref, lam_ref, out_ref):
    y = y_ref[...]          # (T, J)
    w = w_ref[...]          # (T,)
    theta = theta_ref[...]  # (J, d)
    lam_unit = lam_ref[...]  # (J, J) unit lower triangular

    m = d - 1
    cols = _basis_columns(y, d)          # d × (T, J)
    lower = _basis_columns(y, d - 1)     # (d−1) × (T, J)
    mf = float(m)

    # h̃ and h̃' accumulated column-by-column (keeps peak VMEM at
    # 2×(T,J) instead of materializing (T,J,d))
    htil = cols[0] * theta[:, 0]
    hd = (-mf * lower[0]) * theta[:, 0]
    for k in range(1, d):
        htil = htil + cols[k] * theta[:, k]
        if k < m:
            dcol = mf * (lower[k - 1] - lower[k])
        else:
            dcol = mf * lower[m - 1]
        hd = hd + dcol * theta[:, k]

    z = htil @ lam_unit.T
    loss = 0.5 * jnp.sum(z * z, axis=1) - jnp.sum(
        jnp.log(jnp.maximum(hd, ETA_FLOOR)), axis=1
    )
    out_ref[0] = jnp.sum(w * loss)


def nll_tile(y, w, theta, lam_unit):
    """Fused weighted NLL partial sum for one (T, J) tile.

    theta: (J, d) monotone coefficients; lam_unit: (J, J) unit
    lower-triangular copula matrix. Returns a length-1 vector.
    """
    t, j = y.shape
    d = theta.shape[1]
    return pl.pallas_call(
        lambda y_ref, w_ref, th_ref, lam_ref, out_ref: _nll_kernel(
            j, d, y_ref, w_ref, th_ref, lam_ref, out_ref
        ),
        out_shape=jax.ShapeDtypeStruct((1,), y.dtype),
        interpret=True,
    )(y, w, theta, lam_unit)
