"""L1 Pallas kernel: Bernstein design-matrix evaluation.

Computes basis values a = b_{k,m}(y) and derivatives a' for a (T, J)
tile of (already scaled) data in one VMEM-resident pass. VPU-shaped:
elementwise powers with the k-loop unrolled at trace time (d is
static). On a real TPU the whole (T, J, d) output block stays in VMEM
(T=512, J=10, d=7 ⇒ 280 KiB of f64 per tensor — comfortably inside
the ~16 MiB VMEM budget; see DESIGN.md §6). Runs under interpret=True
on CPU — Mosaic custom-calls cannot execute on the CPU PJRT plugin.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _binom_row


def _basis_columns(x, d: int):
    """Unrolled Bernstein columns for a 2-D block x, as a list of (T, J)
    arrays — shared by value and derivative kernels."""
    m = d - 1
    binom = _binom_row(m)
    one_minus = 1.0 - x
    # powers computed incrementally (perf: avoids x**k per column)
    cols = []
    xp = jnp.ones_like(x)  # x^0
    xps = []
    for _ in range(d):
        xps.append(xp)
        xp = xp * x
    cp = jnp.ones_like(x)  # (1-x)^0
    cps = []
    for _ in range(d):
        cps.append(cp)
        cp = cp * one_minus
    for k in range(d):
        cols.append(binom[k] * xps[k] * cps[m - k])
    return cols


def _bernstein_kernel(d: int, y_ref, a_ref, ad_ref):
    y = y_ref[...]  # (T, J)
    m = d - 1
    # values: degree m
    for k, col in enumerate(_basis_columns(y, d)):
        a_ref[..., k] = col
    # derivatives via the degree-(m−1) basis
    lower = _basis_columns(y, d - 1)  # d−1 columns
    mf = float(m)
    ad_ref[..., 0] = -mf * lower[0]
    for k in range(1, m):
        ad_ref[..., k] = mf * (lower[k - 1] - lower[k])
    ad_ref[..., m] = mf * lower[m - 1]


def bernstein_design(y, d: int):
    """Pallas-evaluated design tensors (a, a') of shape (T, J, d)."""
    t, j = y.shape
    out_shape = (
        jax.ShapeDtypeStruct((t, j, d), y.dtype),
        jax.ShapeDtypeStruct((t, j, d), y.dtype),
    )
    return pl.pallas_call(
        lambda y_ref, a_ref, ad_ref: _bernstein_kernel(d, y_ref, a_ref, ad_ref),
        out_shape=out_shape,
        interpret=True,
    )(y)
