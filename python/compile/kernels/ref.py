"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here;
``python/tests`` asserts allclose between kernel and oracle across
shapes/dtypes (hypothesis sweeps). The MCTM math mirrors
``rust/src/mctm/model.rs`` exactly (same parametrization, same loss),
which the Rust integration tests verify end-to-end through the AOT
artifacts.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from functools import partial


# ---------------------------------------------------------------------------
# Bernstein basis
# ---------------------------------------------------------------------------

def _binom_row(m: int):
    """C(m, k) for k = 0..m as a static tuple of floats."""
    row = [1.0]
    for k in range(m):
        row.append(row[-1] * (m - k) / (k + 1))
    return tuple(row)


def bernstein_ref(x, d: int):
    """Bernstein basis values b_{k,m}(x), m = d−1, for x of any shape.

    Returns shape x.shape + (d,).
    """
    m = d - 1
    binom = jnp.asarray(_binom_row(m), dtype=x.dtype)
    k = jnp.arange(d, dtype=x.dtype)
    xe = x[..., None]
    return binom * xe**k * (1.0 - xe) ** (m - k)


def bernstein_deriv_ref(x, d: int):
    """Derivatives b'_{k,m}(x) = m (b_{k−1,m−1} − b_{k,m−1})."""
    m = d - 1
    lower = bernstein_ref(x, d - 1)  # degree m−1, d−1 functions
    left = jnp.concatenate([jnp.zeros_like(lower[..., :1]), lower], axis=-1)
    right = jnp.concatenate([lower, jnp.zeros_like(lower[..., :1])], axis=-1)
    return m * (left - right)


# ---------------------------------------------------------------------------
# Gram / leverage
# ---------------------------------------------------------------------------

def gram_ref(x):
    """XᵀX for a (n, D) matrix."""
    return x.T @ x


def leverage_ref(x, linv):
    """Rowwise ‖L⁻¹ x_i‖² given the inverse Cholesky factor of the Gram
    matrix: the ℓ₂ leverage scores (paper Lemma 2.1 sampling weights)."""
    z = x @ linv.T
    return jnp.sum(z * z, axis=-1)


# ---------------------------------------------------------------------------
# MCTM parametrization + NLL (paper Eq. (1))
# ---------------------------------------------------------------------------

def softplus(x):
    return jnp.logaddexp(x, 0.0)


def unpack_params(params, j: int, d: int):
    """Split the free vector into (β as (J,d), λ lower-tri as (J,J))."""
    beta = params[: j * d].reshape(j, d)
    lam_flat = params[j * d:]
    lam = jnp.zeros((j, j), dtype=params.dtype)
    idx = 0
    for jj in range(1, j):
        lam = lam.at[jj, :jj].set(lam_flat[idx: idx + jj])
        idx += jj
    return beta, lam


def theta_from_beta(beta):
    """Monotone reparametrization: ϑ_0 = β_0, ϑ_k = ϑ_{k−1}+softplus(β_k)."""
    increments = jnp.concatenate([beta[..., :1], softplus(beta[..., 1:])], axis=-1)
    return jnp.cumsum(increments, axis=-1)


ETA_FLOOR = 1e-12


def mctm_nll_ref(params, y, w, j: int, d: int):
    """Weighted MCTM negative log-likelihood over a tile.

    params: (p,) free vector (β then λ row-major)
    y:      (n, J) data already min–max scaled to [eps, 1−eps]
    w:      (n,) weights (0 rows are padding)
    """
    beta, lam = unpack_params(params, j, d)
    theta = theta_from_beta(beta)  # (J, d)
    a = bernstein_ref(y, d)  # (n, J, d)
    ad = bernstein_deriv_ref(y, d)  # (n, J, d)
    htil = jnp.einsum("njd,jd->nj", a, theta)
    hd = jnp.einsum("njd,jd->nj", ad, theta)
    lam_unit = lam + jnp.eye(j, dtype=params.dtype)
    z = htil @ lam_unit.T  # z_j = h̃_j + Σ_{l<j} λ_jl h̃_l
    loss = 0.5 * jnp.sum(z * z, axis=1) - jnp.sum(
        jnp.log(jnp.maximum(hd, ETA_FLOOR)), axis=1
    )
    return jnp.sum(w * loss)


def mctm_nll_grad_ref(params, y, w, j: int, d: int):
    """(value, grad) of the weighted NLL — the fitting objective."""
    f = partial(mctm_nll_ref, y=y, w=w, j=j, d=d)
    return jax.value_and_grad(f)(params)
