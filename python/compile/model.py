"""L2: the MCTM compute graph in JAX, composing the L1 Pallas kernels.

Entry points (all AOT-lowered to HLO text by aot.py, executed from the
Rust coordinator via PJRT — Python is never on the request path):

  * nll_grad(params, y, w)   — weighted NLL value + gradient, the fitting
    objective. Design tensors come from the Pallas Bernstein kernel
    (constants w.r.t. params, so autodiff does not traverse the kernel);
    the θ/λ-dependent tail is jnp, giving an exact reverse-mode gradient
    fused by XLA into the same HLO module.
  * nll_eval(params, y, w)   — forward-only NLL through the fully fused
    Pallas NLL kernel (metrics / LR path).
  * gram(x)                  — tiled XᵀX (leverage pipeline, pass 1).
  * leverage(x, linv)        — rowwise leverage scores (pass 2).

Parametrization matches rust/src/mctm exactly: β-cumsum-softplus for
monotone ϑ, unit-lower-triangular Λ; verified by cross-backend tests.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .kernels import bernstein as bk
from .kernels import gram as gk
from .kernels import leverage as lk
from .kernels import nll as nk
from .kernels.ref import theta_from_beta, unpack_params, ETA_FLOOR


def nll_from_design(params, a, ad, w, j: int, d: int):
    """Weighted NLL given precomputed design tensors (θ-dependent tail)."""
    beta, lam = unpack_params(params, j, d)
    theta = theta_from_beta(beta)
    htil = jnp.einsum("njd,jd->nj", a, theta)
    hd = jnp.einsum("njd,jd->nj", ad, theta)
    lam_unit = lam + jnp.eye(j, dtype=params.dtype)
    z = htil @ lam_unit.T
    loss = 0.5 * jnp.sum(z * z, axis=1) - jnp.sum(
        jnp.log(jnp.maximum(hd, ETA_FLOOR)), axis=1
    )
    return jnp.sum(w * loss)


def nll_grad(params, y, w, j: int, d: int):
    """(value, grad) of the weighted NLL for one (T, J) tile.

    y is pre-scaled data; padding rows carry w = 0.
    """
    a, ad = bk.bernstein_design(y, d)
    # design tensors are constants w.r.t. params — stop_gradient makes
    # that explicit so the VJP never attempts to traverse pallas_call
    a = jax.lax.stop_gradient(a)
    ad = jax.lax.stop_gradient(ad)
    val, grad = jax.value_and_grad(nll_from_design)(params, a, ad, w, j, d)
    return val, grad


def nll_eval(params, y, w, j: int, d: int):
    """Forward-only weighted NLL via the fused Pallas kernel."""
    beta, lam = unpack_params(params, j, d)
    theta = theta_from_beta(beta)
    lam_unit = lam + jnp.eye(j, dtype=params.dtype)
    return nk.nll_tile(y, w, theta, lam_unit)


def gram(x, row_tile: int = 512):
    """Pass-1 of the leverage pipeline (Pallas tiled reduction)."""
    return gk.gram(x, row_tile=row_tile)


def leverage(x, linv, row_tile: int = 512):
    """Pass-2 of the leverage pipeline (Pallas rowwise quadratic form)."""
    return lk.leverage(x, linv, row_tile=row_tile)


def n_params(j: int, d: int) -> int:
    return j * d + j * (j - 1) // 2
